"""Traffic-aware serving-fleet co-exploration via the ExploreSpec facade.

Searches the joint (accelerator config x per-layer precision) space twice
at equal budget — once under per-inference EDP objectives, once under
serving-fleet objectives (p99 latency, energy per served token) where
every candidate replays a shared arrival trace through the
continuous-batching fleet simulator — and shows how queueing pressure
shifts which designs win: the fastest design is no longer automatically
the most efficient per *served* token, because a fast fleet idles.

  PYTHONPATH=src python examples/coexplore_serving.py [--quick]
      [--workload vgg16] [--traffic steady] [--seed 0] [--backend auto]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.dse import ExploreSpec, run
from repro.serving.fleet_sim import simulate_fleet
from repro.serving.traffic import TRAFFIC_PRESETS, make_trace

_MODE_CH = {"fp32": "F", "int16": "I", "lightpe1": "1", "lightpe2": "2"}


def _mode_string(modes) -> str:
    return "".join(_MODE_CH.get(m, m[0].upper()) for m in modes)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small budget/population")
    ap.add_argument("--workload", default="vgg16")
    ap.add_argument("--traffic", default="steady",
                    choices=sorted(TRAFFIC_PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    args = ap.parse_args()

    budget = 256 if args.quick else 1024
    pop = 24 if args.quick else 48
    trace = make_trace(args.traffic)
    print(f"workload={args.workload}  traffic={args.traffic} "
          f"({trace.n_requests} requests, {trace.total_tokens} token-"
          f"iters, slo={trace.slo_s}s)  budget={budget}")

    t0 = time.perf_counter()
    edp = run(ExploreSpec.mixed(
        args.workload, preset="quick", budget=budget, pop_size=pop,
        objectives=("edp", "accuracy_noise"), seed=args.seed,
        backend=args.backend))
    t_edp = time.perf_counter() - t0
    t0 = time.perf_counter()
    serv = run(ExploreSpec.mixed(
        args.workload, preset="quick", budget=budget, pop_size=pop,
        traffic=args.traffic, seed=args.seed, backend=args.backend))
    t_serv = time.perf_counter() - t0

    print(f"\nper-inference EDP search: {t_edp:.1f}s, "
          f"front={edp.front_size}")
    print(f"serving-fleet search:     {t_serv:.1f}s, "
          f"front={serv.front_size}  objectives={serv.objectives}")
    shared = ({g.tobytes() for g in edp.genomes}
              & {g.tobytes() for g in serv.genomes})
    print(f"front overlap: {len(shared)} genomes shared "
          f"(EDP {edp.front_size}, serving {serv.front_size}) — "
          f"traffic pressure re-ranks the design space")

    print(f"\nserving front (best 8 by p99, modes "
          f"F=fp32 I=int16 1/2=LightPE):")
    pts = sorted(serv.front_points(),
                 key=lambda p: p["p99_latency_s"])[:8]
    for p in pts:
        cfg = p["config"]
        print(f"  {cfg.name():40s} {_mode_string(p['modes'])} "
              f"p99={p['p99_latency_s']:.3f}s "
              f"e/tok={p['energy_per_token_j']:.3f}J")

    # replay the trace against the full uniform-precision design space:
    # one aggregates-only sweep feeds the fleet simulator directly
    sweep = run(ExploreSpec.single(args.workload, backend=args.backend,
                                   outputs="aggregates"))
    res = simulate_fleet(sweep.arrays["latency_s"],
                         sweep.arrays["energy_j"], trace, n_slots=8)
    m = res.metrics()
    order = np.lexsort((m["energy_per_token_j"],
                        -m["slo_attainment"]))[:4]
    print(f"\nfleet replay of the uniform design space "
          f"({len(sweep.configs)} configs), best by SLO then e/tok:")
    for i in order:
        print(f"  {sweep.configs[i].name():40s} "
              f"slo={m['slo_attainment'][i]:.2f} "
              f"tput={m['throughput_tps'][i]:.1f} tok/s "
              f"e/tok={m['energy_per_token_j'][i]:.2f}J "
              f"served={m['served_frac'][i]:.2f}")


if __name__ == "__main__":
    main()
