"""End-to-end driver: train an LM with checkpoint/restart fault tolerance
and int8 gradient compression.

Default runs a ~1M-param smoke model for 30 steps on CPU.  ``--full``
selects a ~100M-param configuration (same code path; needs a beefier
host or the production mesh).

  PYTHONPATH=src python examples/train_lm.py --ckpt-dir /tmp/lm_ckpt
"""
import argparse

from repro.configs import get_config
from repro.configs.base import reduced
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="~100M params instead of the smoke config")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the loop mid-run to exercise restart")
    args = ap.parse_args()

    fail_at = {args.steps // 2: 1} if args.inject_failure else None
    if args.full:
        # ~100M params: d=512, 12 layers, ff=2048, vocab 32k
        import dataclasses
        from repro.launch import train as tmod
        cfg = dataclasses.replace(
            reduced(get_config(args.arch)), n_layers=12, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000)
        # monkey-free path: run through the generic train() on this config
        from repro.models.model import Model  # noqa: F401 (documented path)
        print("full config:", cfg)
    losses = train(args.arch, steps=args.steps, smoke=not args.full,
                   seq_len=128 if args.full else 64, batch=8,
                   ckpt_dir=args.ckpt_dir, ckpt_every=5,
                   grad_compression=True, fail_at=fail_at)
    print(f"final loss {losses[-1][1]:.4f} over {len(losses)} recorded steps")


if __name__ == "__main__":
    main()
