"""Quickstart: train a tiny quantization-aware gemma3-family model on CPU.

  PYTHONPATH=src python examples/quickstart.py

What this shows:
  * config -> Model (QAT fake-quant active, LightPE-2/W8A8 analogue)
  * synthetic data pipeline
  * AdamW training loop; loss decreases within ~20 steps
"""
import sys

from repro.launch.train import train


def main():
    losses = train("gemma3-4b", steps=20, smoke=True, seq_len=64, batch=8)
    first, last = losses[0][1], losses[-1][1]
    print(f"\nloss {first:.4f} -> {last:.4f}")
    if last >= first:
        sys.exit("training did not improve loss")
    print("quickstart OK")


if __name__ == "__main__":
    main()
