"""Mixed-precision co-exploration (QADAM/QUIDAM direction): guided search
over the joint (accelerator config x per-layer PE mode) space.

Runs the NSGA-II-style engine against the random baseline at an equal
evaluation budget, prints the shared-reference hypervolumes, the final
Pareto front with each design's per-layer precision string, and the
synthesis-cache reuse the genome encoding buys.

  PYTHONPATH=src python examples/coexplore.py [--quick] [--workload vgg16]
      [--seed 0] [--backend auto]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.dse import ExploreSpec, run
from repro.core.synthesis import (clear_synthesis_cache,
                                  synthesis_cache_stats)
from repro.explore.objectives import mode_sqnr_db
from repro.explore.pareto import hypervolume, reference_point

_MODE_CH = {"fp32": "F", "int16": "I", "lightpe1": "1", "lightpe2": "2"}


def _mode_string(modes) -> str:
    # unknown (future) modes print as their first letter instead of crashing
    return "".join(_MODE_CH.get(m, m[0].upper()) for m in modes)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: small budget/population")
    ap.add_argument("--workload", default="vgg16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto")
    args = ap.parse_args()

    preset = "quick" if args.quick else "default"
    print(f"workload={args.workload}  preset={preset}  seed={args.seed}")
    print("per-mode SQNR (dB):",
          {k: round(v, 1) for k, v in mode_sqnr_db().items()
           if v != float("inf")})

    clear_synthesis_cache()
    t0 = time.perf_counter()
    guided = run(ExploreSpec.mixed(args.workload, preset=preset,
                                   seed=args.seed, backend=args.backend))
    t_guided = time.perf_counter() - t0
    t0 = time.perf_counter()
    rand = run(ExploreSpec.mixed(args.workload, preset=preset,
                                 method="random", seed=args.seed,
                                 backend=args.backend))
    t_rand = time.perf_counter() - t0

    # one shared reference point makes the two hypervolumes comparable
    ref = reference_point(np.concatenate([guided.all_objectives,
                                          rand.all_objectives]))
    hv_g = hypervolume(guided.front_objectives, ref)
    hv_r = hypervolume(rand.front_objectives, ref)
    print(f"\nnsga2 : {guided.n_evals} evals in {t_guided:.2f}s  "
          f"front={guided.front_size}  hypervolume={hv_g:.5g}")
    print(f"random: {rand.n_evals} evals in {t_rand:.2f}s  "
          f"front={rand.front_size}  hypervolume={hv_r:.5g}")
    print(f"guided/random hypervolume: {hv_g / max(hv_r, 1e-300):.3f}x")

    stats = synthesis_cache_stats()
    hits, misses = stats["array_hits"], stats["array_misses"]
    print(f"synthesis cache: {hits} hits / {misses} misses "
          f"({hits / max(1, hits + misses):.1%} hit rate — every genome "
          f"keyed through confighash)")

    print("\nfront (modes per layer: F=fp32 I=int16 1=lightpe1 "
          "2=lightpe2):")
    for pt in guided.front_points()[:10]:
        cfg = pt["config"]
        print(f"  {cfg.pe_type.value:9s} {cfg.pe_rows}x{cfg.pe_cols:<3d}"
              f" glb{cfg.glb_kb:<4d} [{_mode_string(pt['modes'])}]"
              f"  perf/area={-pt['neg_perf_per_area']:8.1f}"
              f"  energy={pt['energy_j'] * 1e3:7.3f} mJ"
              f"  noise={pt['accuracy_noise']:.2e}")

    print("\nhypervolume vs evaluations (guided, own reference):")
    for evals, hv in guided.history[:: max(1, len(guided.history) // 8)]:
        print(f"  {evals:6d}  {hv:.5g}")


if __name__ == "__main__":
    main()
