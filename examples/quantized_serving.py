"""Serve a small model with batched requests and quantized weights —
the LightPE deployment path (paper Sec. 3.2 -> TPU W8A8/W4A8).

  PYTHONPATH=src python examples/quantized_serving.py
"""
import numpy as np

from repro.launch.serve import serve


def main():
    print("float (bf16) serving:")
    a = serve("starcoder2-7b", batch=4, prompt_len=12, gen=8, smoke=True,
              quantize=False, seed=7)
    print(f"  tokens {a['tokens'].shape}, {a['tok_per_s']:.1f} tok/s")

    print("quantized (W8A8, LightPE-2 analogue) serving:")
    b = serve("starcoder2-7b", batch=4, prompt_len=12, gen=8, smoke=True,
              quantize=True, seed=7)
    print(f"  tokens {b['tokens'].shape}, {b['tok_per_s']:.1f} tok/s")

    agree = float(np.mean(np.asarray(a["tokens"]) == np.asarray(b["tokens"])))
    print(f"greedy-token agreement float vs int8: {agree * 100:.0f}%")


if __name__ == "__main__":
    main()
