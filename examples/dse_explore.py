"""The paper's headline experiment (Figs. 3-5): design-space exploration
over PE types on VGG-16, normalized against the best INT16 config.

  PYTHONPATH=src python examples/dse_explore.py [workload]
"""
import sys

from repro.core.dse import explore, pareto_front
from repro.core.pe import PEType


def main():
    wl = sys.argv[1] if len(sys.argv) > 1 else "vgg16"
    res = explore(wl)
    print(f"workload={wl}  design points={len(res.points)}")
    print("\nbest config per PE type (perf/area anchor = best INT16):")
    anchor = res.best_perf_per_area(PEType.INT16)
    for t in PEType:
        b = res.best_perf_per_area(t)
        e = res.best_energy(t)
        print(f"  {t.pretty:10s} perf/area {b.perf_per_area:8.1f} GMAC/s/mm^2"
              f" ({b.perf_per_area / anchor.perf_per_area:4.2f}x)"
              f"  best-energy {e.energy_j * 1e3:7.3f} mJ"
              f"   [{b.config.name()}]")
    print("\nheadline ratios (paper: 4.9/4.9, 4.1/4.2, 1.7/1.4):")
    for k, v in res.headline_ratios().items():
        print(f"  {k}: {v:.2f}")
    front = pareto_front(res.points)
    print(f"\nPareto frontier ({len(front)} points, all should be LightPE):")
    for p in front[:10]:
        print(f"  {p.config.pe_type.value:9s} perf/area="
              f"{p.perf_per_area:8.1f} energy={p.energy_j * 1e3:7.3f} mJ")


if __name__ == "__main__":
    main()
