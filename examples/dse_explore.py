"""The paper's headline experiment (Figs. 3-5): design-space exploration
over PE types on VGG-16, normalized against the best INT16 config.

Runs on the vectorized batched sweep engine (all configs x all layers as
fused array ops), then demonstrates the incremental-sweep API by widening
the design space without re-evaluating known points.

  PYTHONPATH=src python examples/dse_explore.py [workload]
"""
import sys
import time

from repro.core.accelerator import design_space
from repro.core.dse import ExploreSpec, IncrementalSweep, pareto_front, run
from repro.core.pe import PEType
from repro.core.synthesis import synthesis_cache_stats


def main():
    wl = sys.argv[1] if len(sys.argv) > 1 else "vgg16"
    t0 = time.perf_counter()
    res = run(ExploreSpec.single(wl))      # batched engine (default)
    dt = time.perf_counter() - t0
    print(f"workload={wl}  design points={len(res.points)}  "
          f"sweep={dt * 1e3:.1f} ms (batched engine)")
    print("\nbest config per PE type (perf/area anchor = best INT16):")
    anchor = res.best_perf_per_area(PEType.INT16)
    for t in PEType:
        b = res.best_perf_per_area(t)
        e = res.best_energy(t)
        print(f"  {t.pretty:10s} perf/area {b.perf_per_area:8.1f} GMAC/s/mm^2"
              f" ({b.perf_per_area / anchor.perf_per_area:4.2f}x)"
              f"  best-energy {e.energy_j * 1e3:7.3f} mJ"
              f"   [{b.config.name()}]")
    print("\nheadline ratios (paper: 4.9/4.9, 4.1/4.2, 1.7/1.4):")
    for k, v in res.headline_ratios().items():
        print(f"  {k}: {v:.2f}")
    front = pareto_front(res.points)
    print(f"\nPareto frontier ({len(front)} points, all should be LightPE):")
    for p in front[:10]:
        print(f"  {p.config.pe_type.value:9s} perf/area="
              f"{p.perf_per_area:8.1f} energy={p.energy_j * 1e3:7.3f} mJ")

    # --- incremental sweep: widen the space, pay only for the new points ---
    sweep = IncrementalSweep(wl, design_space())
    t0 = time.perf_counter()
    added = sweep.extend(design_space(glb_kbs=(1024,)))   # new GLB column
    dt = time.perf_counter() - t0
    stats = synthesis_cache_stats()
    print(f"\nincremental extend: +{added} new points in {dt * 1e3:.1f} ms "
          f"(sweep now {len(sweep)}; synthesis array cache: "
          f"{stats['array_hits']} hits / {stats['array_misses']} misses)")
    r2 = sweep.result().headline_ratios()
    print(f"  lightpe1 perf/area vs int16 on widened space: "
          f"{r2['lightpe1_perf_per_area_vs_int16']:.2f}")


if __name__ == "__main__":
    main()
