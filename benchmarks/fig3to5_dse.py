"""Paper Figs. 3-5: DSE Pareto + headline ratios per workload.

Reports, for VGG-16 / ResNet-34 / ResNet-50: the normalized ratios of the
best LightPE-1/LightPE-2 configs vs the best INT16 config and INT16 vs
FP32 (paper: 4.9x/4.9x, 4.1x/4.2x, 1.7x/1.4x), plus sweep timing.

Sweeps all three workloads with the batched engine's ``explore_many`` (one
synthesis pass shared across workloads); the scalar path is covered by
``benchmarks/dse_sweep_bench.py``.
"""

import time

import numpy as np

from repro.core.dse import ExploreSpec, pareto_front
from repro.core.dse import run as run_spec


def run():
    rows = []
    agg = {}
    wls = ("vgg16", "resnet34", "resnet50")
    t0 = time.perf_counter()
    results = run_spec(ExploreSpec.many(wls))
    dt_all = time.perf_counter() - t0
    for wl in wls:
        res = results[wl]
        n = len(res.points)
        r = res.headline_ratios()
        for k, v in r.items():
            rows.append((f"dse/{wl}/{k}", 0.0, f"{v:.2f}"))
            agg.setdefault(k, []).append(v)
        front = pareto_front(res.points)
        rows.append((f"dse/{wl}/pareto_size", 0.0, str(len(front))))
    rows.append(("dse/sweep_3wl_batched", dt_all / (3 * n) * 1e6,
                 f"us_per_design_point(n={3 * n})"))
    paper = {"lightpe1_perf_per_area_vs_int16": 4.9,
             "lightpe1_energy_vs_int16": 4.9,
             "lightpe2_perf_per_area_vs_int16": 4.1,
             "lightpe2_energy_vs_int16": 4.2,
             "int16_perf_per_area_vs_fp32": 1.7,
             "int16_energy_vs_fp32": 1.4}
    for k, vals in agg.items():
        got = float(np.mean(vals))
        rows.append((f"dse/mean/{k}", 0.0,
                     f"{got:.2f}_vs_paper_{paper[k]}"))
    return rows
