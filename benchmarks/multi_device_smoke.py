"""Multi-device smoke: the sharded + pipelined paths on forced host
devices (the `multi-device-smoke` CI job).

Must be launched with ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
already in the environment (before any jax import): asserts
``jax.device_count()`` matches ``--devices``, then

* shards ``sweep_mixed_many`` over the full device mesh and checks the
  result against the single-device (unsharded) numpy and jax outputs —
  numpy simulated shards bit-exact, jax ``shard_map`` ≤1e-6 relative —
  for both a divisible and a non-divisible batch size;
* runs the double-buffered ``sweep_chunked`` pipeline on the device mesh
  and checks its Pareto front is identical to the serial single-device
  sweep, recording serial/pipelined throughput and the overlap fraction;
* runs a short mesh-sharded ``coexplore_many`` search and checks its
  front matches the unsharded numpy search bit for bit.

Writes one JSON report (``--out``, uploaded as a CI artifact) and exits
non-zero on any parity failure.

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      PYTHONPATH=src python benchmarks/multi_device_smoke.py --devices 4
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

RTOL = 1e-6
_PARITY_KEYS = ("latency_s", "energy_j", "perf_per_area",
                "throughput_gmacs")


def _max_rel(a: dict, b: dict, keys=_PARITY_KEYS) -> float:
    worst = 0.0
    for k in keys:
        x = np.asarray(a[k], dtype=np.float64)
        y = np.asarray(b[k], dtype=np.float64)
        both_zero = (x == 0) & (y == 0)
        denom = np.where(x == 0, 1.0, x)
        worst = max(worst, float(np.max(np.where(
            both_zero, 0.0, np.abs(y / denom - 1.0)))))
    return worst


def _mixed_many_batch(n: int, seed: int = 5):
    from repro.core.accelerator import AcceleratorConfig, configs_to_soa
    from repro.core.pe import PEType, supported_modes
    from repro.core.workloads import get_workload

    types = tuple(PEType)
    wls = (get_workload("vgg16"), get_workload("resnet34"),
           get_workload("resnet50"))
    rng = np.random.default_rng(seed)
    space = [AcceleratorConfig(pe_type=t, pe_rows=r, pe_cols=c, glb_kb=g,
                               dram_bw_gbps=bw)
             for t in types
             for (r, c, g, bw) in [(8, 8, 64, 6.4), (12, 14, 128, 12.8),
                                   (16, 16, 256, 12.8),
                                   (32, 32, 512, 25.6)]]
    configs = [space[i] for i in rng.integers(0, len(space), size=n)]
    soa = configs_to_soa(configs)
    assigns = []
    for w in wls:
        a = np.empty((n, len(w.layers)), dtype=np.int64)
        for i, c in enumerate(configs):
            modes = [types.index(m) for m in supported_modes(c.pe_type)]
            a[i] = rng.choice(modes, size=len(w.layers))
        assigns.append(a)
    return wls, soa, assigns


def smoke_sharded_many(mesh, n_devices: int) -> dict:
    from repro.core.dse_batch import sweep_mixed_many

    out: dict = {}
    for n in (16 * n_devices, 16 * n_devices + 3):   # divisible + ragged
        wls, soa, assigns = _mixed_many_batch(n)
        un_np = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                                 use_cache=False)
        sh_np = sweep_mixed_many(wls, soa, assigns, backend="numpy",
                                 use_cache=False, mesh=n_devices)
        sh_j = sweep_mixed_many(wls, soa, assigns, backend="jax",
                                use_cache=False, mesh=mesh)
        tag = f"n{n}"
        out[f"sharded_many_{tag}_numpy_bit_exact"] = bool(all(
            np.array_equal(un_np[k], sh_np[k]) for k in un_np))
        out[f"sharded_many_{tag}_jax_max_rel"] = _max_rel(un_np, sh_j)
    return out


def smoke_pipelined_chunked(mesh) -> dict:
    from repro.core.accelerator import design_space_soa
    from repro.core.dse_batch import sweep_chunked
    from repro.core.workloads import get_workload

    wl = get_workload("vgg16")
    grid = dict(glb_kbs=(64, 128, 256, 512),
                bws=tuple(np.linspace(2.0, 64.0, 64)))
    chunk_size = 4096

    def space():
        return design_space_soa(chunk_size=chunk_size, **grid)

    n = sum(len(s["pe_rows"]) for s in space())
    out: dict = {"chunked_n_configs": n}
    runs = {}
    for name, kwargs in (
            ("serial", dict(backend="numpy", overlap=False)),
            ("pipelined", dict(backend="numpy", overlap=True)),
            ("pipelined_jax_mesh", dict(backend="jax", overlap=True,
                                        mesh=mesh))):
        best, res = float("inf"), None
        for _ in range(2):                      # 1 warmup
            t0 = time.perf_counter()
            res = sweep_chunked(wl, space(), chunk_size=chunk_size,
                                **kwargs)
            best = min(best, time.perf_counter() - t0)
        runs[name] = res
        out[f"chunked_{name}_s"] = best
        out[f"chunked_{name}_configs_per_s"] = n / best
    out["chunked_pipeline_speedup"] = (out["chunked_serial_s"]
                                       / out["chunked_pipelined_s"])
    out["chunked_overlap_fraction"] = max(
        0.0, 1.0 - out["chunked_pipelined_s"] / out["chunked_serial_s"])
    fm_s = runs["serial"].front_metrics
    fm_p = runs["pipelined"].front_metrics
    out["chunked_pipeline_front_identical"] = bool(all(
        np.array_equal(fm_s[m], fm_p[m]) for m in fm_s))
    fm_j = runs["pipelined_jax_mesh"].front_metrics
    out["chunked_jax_mesh_front_max_rel"] = (
        float("inf") if fm_j["energy_j"].shape != fm_s["energy_j"].shape
        else _max_rel(
            {m: np.sort(fm_s[m]) for m in fm_s},
            {m: np.sort(fm_j[m]) for m in fm_j},
            keys=tuple(fm_s)))
    return out


def smoke_sharded_search(mesh) -> dict:
    from repro.core.dse import coexplore_many

    wls = ("vgg16", "resnet34", "resnet50")
    base = coexplore_many(wls, preset="many-quick", budget=96, seed=11,
                          backend="numpy")
    t0 = time.perf_counter()
    sharded = coexplore_many(wls, preset="many-quick", budget=96, seed=11,
                             backend="jax", mesh=mesh)
    dt = time.perf_counter() - t0

    def _row_sorted(g):
        return g[np.lexsort(g.T[::-1])]

    return {
        "search_sharded_evals_per_s": sharded.n_evals / dt,
        "search_mesh_shards": sharded.stats["mesh_shards"],
        "search_sharded_front_matches_numpy": bool(
            base.genomes.shape == sharded.genomes.shape
            and np.array_equal(_row_sorted(base.genomes),
                               _row_sorted(sharded.genomes))),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=4,
                    help="expected jax.device_count()")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("/tmp/bench_multi_device.json"))
    args = ap.parse_args()

    assert "xla_force_host_platform_device_count" in \
        os.environ.get("XLA_FLAGS", ""), \
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=K"

    import jax

    from repro.launch.mesh import make_sweep_mesh

    n_devices = jax.device_count()
    r: dict = {"expected_devices": args.devices,
               "device_count": n_devices,
               "provenance": provenance()}
    failures: list[str] = []
    if n_devices != args.devices:
        failures.append(
            f"jax.device_count() == {n_devices}, expected {args.devices}")

    mesh = make_sweep_mesh()
    r.update(smoke_sharded_many(mesh, n_devices))
    r.update(smoke_pipelined_chunked(mesh))
    r.update(smoke_sharded_search(mesh))

    for k, v in sorted(r.items()):
        if k == "provenance":
            continue
        print(f"{k}: {v}")
        if k.endswith("_bit_exact") or k.endswith("_identical") \
                or k.endswith("_matches_numpy"):
            if not v:
                failures.append(f"{k} is False")
        elif k.endswith("_max_rel") and v >= RTOL:
            failures.append(f"{k} = {v:.3g} >= {RTOL}")

    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("multi-device smoke FAILED:\n  "
                         + "\n  ".join(failures))
    print(f"multi-device smoke OK on {n_devices} devices")


if __name__ == "__main__":
    main()
