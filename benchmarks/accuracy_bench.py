"""Accuracy-tier benchmark: proxy vs calibrated vs measured (ISSUE 10).

Measures the cost of each accuracy tier and gates the claims the tiered
subsystem makes:

* **tier-1 fidelity** — Spearman rank correlation between tier-0 proxy
  and tier-1 calibrated scores over 512 random genomes must stay >= 0.8
  (the calibrated table refines the proxy, it does not contradict it);
* **tier-1 cost** — cold calibration wall time (real zoo tensors through
  the real quantizers) and the npz-cache hit on re-run (a warm load must
  actually hit the cache, and costs ~ms);
* **front shift** — the committed ``calibrated-quick`` preset must select
  a different Pareto-front membership than the proxy ``quick`` campaign
  at the same seed/budget;
* **tier-2 cost** — quantized-forward elite validation on the smallest
  zoo model must finish in under 120 s;
* **backend parity** — an nsga2 campaign under the calibrated table is
  bit-identical between the numpy and jax evaluation backends.

Emits ``BENCH_accuracy.json`` so the trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/accuracy_bench.py [--quick]
      [--out BENCH_accuracy.json] [--check-against BENCH_accuracy.json]
      [--regen-golden]

``--check-against`` additionally fails on a >3x cold-calibration slowdown
vs the committed baseline; ``--regen-golden`` rewrites
``tests/golden_calibrated_front.json`` from the committed preset.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

from repro.core.dse import ExploreSpec, run as run_spec  # noqa: E402
from repro.core.dse_batch import resolve_backend  # noqa: E402
from repro.core.workloads import get_workload  # noqa: E402
from repro.explore.accuracy import (AccuracySpec,  # noqa: E402
                                    CalibratedAccuracy, validate_elites)
from repro.explore.objectives import quant_noise  # noqa: E402
from repro.explore.search import nsga2  # noqa: E402
from repro.explore.space import space_for_workload  # noqa: E402
from repro.quant.calibrate import (calibrate_model,  # noqa: E402
                                   calibration_cache_stats,
                                   reset_calibration_cache_stats)

REPO = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO / "BENCH_accuracy.json"
GOLDEN = REPO / "tests" / "golden_calibrated_front.json"

MODEL = "mamba2-130m"                  # smallest zoo config
SPEARMAN_FLOOR = 0.8
TIER2_BUDGET_S = 120.0


def spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation with average ranks for ties (Pearson of
    the rank vectors) — no scipy dependency."""
    def avg_ranks(x: np.ndarray) -> np.ndarray:
        order = np.argsort(x, kind="mergesort")
        xs = x[order]
        r = np.empty(len(x), dtype=np.float64)
        i = 0
        while i < len(xs):
            j = i
            while j + 1 < len(xs) and xs[j + 1] == xs[i]:
                j += 1
            r[order[i:j + 1]] = 0.5 * (i + j)
            i = j + 1
        return r

    ra, rb = avg_ranks(np.asarray(a)), avg_ranks(np.asarray(b))
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / max(denom, 1e-300))


def bench(quick: bool = False, seed: int = 0, with_jax: bool = True) -> dict:
    wl_name = "vgg16"
    wl = get_workload(wl_name)
    space = space_for_workload(wl)
    macs = np.array([l.macs for l in wl.layers], dtype=np.float64)

    out: dict = {"quick": quick, "seed": seed, "model": MODEL,
                 "workload": wl_name, "provenance": provenance()}

    # -- tier-1 calibration cost + cache hit on re-run ----------------------
    t0 = time.perf_counter()
    tab = calibrate_model(MODEL, refresh=True)      # cold: real measurement
    out["calibrate_cold_s"] = time.perf_counter() - t0
    reset_calibration_cache_stats()
    t0 = time.perf_counter()
    tab2 = calibrate_model(MODEL)
    out["calibrate_warm_s"] = time.perf_counter() - t0
    stats = calibration_cache_stats()
    out["cache_hit_on_rerun"] = stats == {"hits": 1, "misses": 0}
    out["calibration_digest"] = tab.digest()
    out["calibration_layers"] = tab.n_layers
    assert tab2.digest() == tab.digest()

    # -- tier-1 vs tier-0 rank fidelity on 512 genomes ----------------------
    cal = CalibratedAccuracy(AccuracySpec(tier=1, model=MODEL))
    n_genomes = 512
    _, assign = space.decode(space.random_population(
        n_genomes, np.random.default_rng(seed)))
    t0 = time.perf_counter()
    s0 = quant_noise(assign, macs)
    out["tier0_score_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    s1 = cal.score(assign, macs)
    out["tier1_score_s"] = time.perf_counter() - t0
    out["n_genomes"] = n_genomes
    out["tier1_vs_tier0_spearman"] = spearman(s0, s1)

    # -- front shift: the committed calibrated-quick preset -----------------
    res_cal = run_spec(ExploreSpec.mixed(wl_name, preset="calibrated-quick",
                                         seed=seed, backend="numpy"))
    res_prox = run_spec(ExploreSpec.mixed(wl_name, preset="quick",
                                          seed=seed, backend="numpy"))
    keys_cal = set(res_cal.space.genome_keys(res_cal.genomes))
    keys_prox = set(res_prox.space.genome_keys(res_prox.genomes))
    out["calibrated_front_size"] = len(keys_cal)
    out["proxy_front_size"] = len(keys_prox)
    out["front_membership_differs"] = keys_cal != keys_prox
    out["front_jaccard"] = (len(keys_cal & keys_prox)
                            / max(1, len(keys_cal | keys_prox)))

    # -- tier 2: quantized-forward elite validation -------------------------
    budget, pop = (96, 12) if quick else (384, 24)
    spec2 = AccuracySpec(tier=2, model=MODEL, max_elites=8)
    res2 = nsga2(space, wl, budget, pop_size=pop, seed=seed,
                 backend="numpy", accuracy=spec2)
    t0 = time.perf_counter()
    v = validate_elites(res2, spec2)
    out["tier2_validation_s"] = time.perf_counter() - t0
    out["tier2_n_elites"] = int(len(v.elite_indices))
    out["tier2_baseline_loss"] = float(v.baseline_loss)
    out["tier2_max_loss_delta"] = float(v.loss_delta.max())
    out["tier2_n_surviving"] = int(v.pareto_mask.sum())
    out["tier2_within_budget"] = out["tier2_validation_s"] < TIER2_BUDGET_S

    # -- backend parity under the calibrated table --------------------------
    if with_jax:
        try:
            resolve_backend("jax")
        except RuntimeError:
            pass
        else:
            res_np = nsga2(space, wl, budget, pop_size=pop, seed=seed,
                           backend="numpy", accuracy=cal)
            res_jx = nsga2(space, wl, budget, pop_size=pop, seed=seed,
                           backend="jax", accuracy=cal)

            def row_sorted(g):
                return g[np.lexsort(g.T[::-1])]

            out["jax_front_matches_numpy"] = (
                res_np.genomes.shape == res_jx.genomes.shape
                and bool(np.array_equal(row_sorted(res_np.genomes),
                                        row_sorted(res_jx.genomes))))
    return out


def regen_golden(seed: int = 0) -> None:
    """Rewrite tests/golden_calibrated_front.json from the committed
    ``calibrated-quick`` preset (run after an intentional change to the
    calibrator, the quantizers, or the search engine)."""
    res = run_spec(ExploreSpec.mixed("vgg16", preset="calibrated-quick",
                                     seed=seed, backend="numpy"))
    prox = run_spec(ExploreSpec.mixed("vgg16", preset="quick", seed=seed,
                                      backend="numpy"))
    ck = set(res.space.genome_keys(res.genomes))
    pk = set(prox.space.genome_keys(prox.genomes))
    if ck == pk:
        raise SystemExit("calibrated-quick front membership no longer "
                         "differs from the proxy's — the golden claim "
                         "would be vacuous; investigate before committing")
    golden = {
        "preset": "calibrated-quick", "workload": "vgg16", "seed": seed,
        "backend": "numpy", "pop_size": 24, "budget": 384,
        "objectives": list(res.objectives),
        "calibration_digest": calibrate_model(MODEL).digest(),
        "front_genomes_u16": res.space.pack_genomes(res.genomes).tolist(),
        "front_objectives": np.asarray(res.front_objectives).tolist(),
    }
    GOLDEN.write_text(json.dumps(golden, indent=1) + "\n")
    print(f"wrote {GOLDEN} ({len(golden['front_genomes_u16'])} front "
          f"genomes, symm-diff vs proxy {len(ck ^ pk)})")


def check_against(r: dict, baseline_path: pathlib.Path) -> None:
    """CI gate: >3x cold-calibration slowdown vs the committed baseline
    fails (same pattern as the other benches)."""
    base = json.loads(baseline_path.read_text())
    base_s, got_s = base["calibrate_cold_s"], r["calibrate_cold_s"]
    print(f"regression check: cold calibration {got_s:.2f}s vs baseline "
          f"{base_s:.2f}s (ceiling {base_s * 3:.2f}s)")
    if got_s > base_s * 3.0:
        raise SystemExit(
            f"tier-1 calibration regressed >3x: {got_s:.2f}s vs "
            f"baseline {base_s:.2f}s")


def enforce_gates(r: dict) -> None:
    """The accuracy-smoke claims, enforced on every run (no baseline
    needed: these are absolute contracts, not throughput trends)."""
    if r["tier1_vs_tier0_spearman"] < SPEARMAN_FLOOR:
        raise SystemExit(
            f"tier-1/tier-0 Spearman {r['tier1_vs_tier0_spearman']:.3f} "
            f"fell below {SPEARMAN_FLOOR}: the calibrated table "
            f"contradicts the proxy ordering")
    if not r["cache_hit_on_rerun"]:
        raise SystemExit("calibration npz cache missed on re-run")
    if not r["front_membership_differs"]:
        raise SystemExit("calibrated-quick selected the same front as the "
                         "proxy — the tier-1 signal is not reaching the "
                         "search")
    if not r["tier2_within_budget"]:
        raise SystemExit(
            f"tier-2 elite validation took {r['tier2_validation_s']:.1f}s "
            f"(budget {TIER2_BUDGET_S:.0f}s)")
    if not r.get("jax_front_matches_numpy", True):
        raise SystemExit("calibrated nsga2 front differs between numpy "
                         "and jax backends")


def run():
    """benchmarks/run.py entry: CSV rows (name, us_per_call, derived)."""
    r = bench(quick=True)
    enforce_gates(r)
    return [
        ("accuracy/calibrate_cold", r["calibrate_cold_s"] * 1e6,
         f"layers={r['calibration_layers']}"),
        ("accuracy/calibrate_warm", r["calibrate_warm_s"] * 1e6,
         f"cache_hit={r['cache_hit_on_rerun']}"),
        ("accuracy/tier1_score_512", r["tier1_score_s"] * 1e6,
         f"spearman={r['tier1_vs_tier0_spearman']:.3f}"),
        ("accuracy/tier2_validate", r["tier2_validation_s"] * 1e6,
         f"elites={r['tier2_n_elites']}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced tier-2 campaign (CI smoke mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--check-against", type=pathlib.Path, default=None,
                    help="baseline BENCH json; fail on >3x regression")
    ap.add_argument("--regen-golden", action="store_true",
                    help="rewrite tests/golden_calibrated_front.json")
    args = ap.parse_args()

    if args.regen_golden:
        regen_golden(seed=args.seed)
        return

    r = bench(quick=args.quick, seed=args.seed)
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")

    print(f"model: {r['model']}  ({r['calibration_layers']} layers)"
          f"{'  (quick)' if r['quick'] else ''}")
    print(f"calibrate  cold {r['calibrate_cold_s'] * 1e3:8.1f} ms   "
          f"warm {r['calibrate_warm_s'] * 1e3:6.1f} ms   "
          f"cache hit: {r['cache_hit_on_rerun']}")
    print(f"tier1 vs tier0 on {r['n_genomes']} genomes: "
          f"spearman {r['tier1_vs_tier0_spearman']:.3f}   "
          f"(score {r['tier1_score_s'] * 1e3:.1f} ms vs "
          f"{r['tier0_score_s'] * 1e3:.1f} ms)")
    print(f"front shift (calibrated-quick vs quick): "
          f"{r['calibrated_front_size']} vs {r['proxy_front_size']} "
          f"genomes, jaccard {r['front_jaccard']:.3f}, "
          f"differs: {r['front_membership_differs']}")
    print(f"tier2 validation: {r['tier2_validation_s']:.1f} s for "
          f"{r['tier2_n_elites']} elites "
          f"({r['tier2_n_surviving']} survive measured re-scoring)")
    if "jax_front_matches_numpy" in r:
        print(f"jax front matches numpy: {r['jax_front_matches_numpy']}")
    print(f"wrote {args.out}")

    if args.check_against is not None:
        check_against(r, args.check_against)
    enforce_gates(r)


if __name__ == "__main__":
    main()
