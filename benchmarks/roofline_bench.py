"""§Roofline: aggregate the dry-run JSONs into the roofline table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch x shape x mesh): the three terms, the bottleneck,
and the roofline fraction.  Run the dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import glob
import json
import os

_DEFAULT = "experiments/dryrun_v3" \
    if os.path.isdir("experiments/dryrun_v3") else "experiments/dryrun"
DRYRUN_DIR = os.environ.get("DRYRUN_DIR", _DEFAULT)


def run():
    rows = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        return [("roofline/missing", 0.0,
                 "run repro.launch.dryrun --all first")]
    ok = skipped = failed = 0
    for f in files:
        rec = json.load(open(f))
        tag = f"{rec['arch']}x{rec['shape']}x{rec['mesh']}"
        if rec["status"] == "skipped":
            skipped += 1
            rows.append((f"roofline/{tag}", 0.0, "skipped_subquadratic"))
            continue
        if rec["status"] != "ok":
            failed += 1
            rows.append((f"roofline/{tag}", 0.0, "ERROR"))
            continue
        ok += 1
        r = rec["roofline"]
        rows.append((
            f"roofline/{tag}",
            r["step_time_s"] * 1e6,
            (f"bound={r['bottleneck']};frac={r['roofline_fraction']:.4f};"
             f"c={r['compute_s']:.3f}s;m={r['memory_s']:.3f}s;"
             f"n={r['collective_s']:.3f}s;useful={r['useful_flops_ratio']:.2f}")))
    rows.append(("roofline/summary", 0.0,
                 f"ok={ok};skipped={skipped};failed={failed}"))
    return rows
