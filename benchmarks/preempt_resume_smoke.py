"""Preempt/resume smoke: a real SIGKILL mid-stream, then a resume that
must reproduce the uninterrupted Pareto front bit for bit (the
`preempt-resume-smoke` CI job).

The parent process

* computes the uninterrupted reference front in-process (numpy);
* spawns this script with ``--child``: a chunked sweep of the same feed,
  throttled so it checkpoints every chunk, and SIGKILLs it once enough
  snapshots exist on disk — a genuine preemption, not an injected
  exception (the in-exception restart path is covered by
  tests/test_dse_checkpoint.py);
* resumes the dead run via :func:`repro.runtime.dse_checkpoint
  .resume_sweep` on the same checkpoint directory and verifies the
  resumed front, config count, and chunk count are identical to the
  reference.

Writes one JSON report (``--out``, uploaded as a CI artifact alongside
the checkpoint directory on failure) and exits non-zero on any mismatch.

  PYTHONPATH=src python benchmarks/preempt_resume_smoke.py
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

CHUNK = 16
# a bandwidth-rich grid so the reference front has real extent (a trivial
# one-point front would make the bit-identity gate vacuous)
GRID = dict(glb_kbs=(64, 128, 256, 512),
            bws=tuple(float(b) for b in np.linspace(2.0, 64.0, 16)))


def _space():
    from repro.core.accelerator import design_space_soa
    return design_space_soa(chunk_size=CHUNK, **GRID)


def _throttled_space(delay_s: float):
    """The same feed, slowed down so the parent can preempt mid-stream."""
    for soa in _space():
        time.sleep(delay_s)
        yield soa


def run_child(ckpt_dir: str, delay_s: float) -> None:
    from repro import obs
    from repro.core.dse_batch import _sweep_chunked
    from repro.core.workloads import get_workload
    from repro.runtime.dse_checkpoint import SweepCheckpointer

    # the JSONL event log lives next to the checkpoints and must survive
    # the SIGKILL the same way they do (flushed per closed span)
    obs.configure(enabled=True,
                  jsonl_path=os.path.join(ckpt_dir, "trace.jsonl"))
    ck = SweepCheckpointer(ckpt_dir, every=1)
    _sweep_chunked(get_workload("vgg16"), _throttled_space(delay_s),
                   chunk_size=CHUNK, backend="numpy", checkpoint=ck)
    # the parent kills us long before the stream drains; reaching the end
    # means the kill never landed
    print("child finished unexpectedly", file=sys.stderr)
    raise SystemExit(3)


def _snapshots(ckpt_dir: pathlib.Path) -> list[str]:
    if not ckpt_dir.is_dir():
        return []
    return sorted(d for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and not d.endswith(".tmp"))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/preempt_resume_ckpt")
    ap.add_argument("--delay-s", type=float, default=0.2,
                    help="child per-chunk throttle")
    ap.add_argument("--kill-after", type=int, default=3,
                    help="SIGKILL the child once this many snapshots exist")
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("/tmp/bench_preempt_resume.json"))
    args = ap.parse_args()

    if args.child:
        run_child(args.ckpt, args.delay_s)
        return

    from repro.core.dse_batch import _sweep_chunked
    from repro.core.workloads import get_workload
    from repro.runtime.dse_checkpoint import resume_sweep

    wl = get_workload("vgg16")
    ref = _sweep_chunked(wl, _space(), chunk_size=CHUNK, backend="numpy")

    ckpt_dir = pathlib.Path(args.ckpt)
    if ckpt_dir.exists():
        import shutil
        shutil.rmtree(ckpt_dir)

    child = subprocess.Popen(
        [sys.executable, __file__, "--child", "--ckpt", str(ckpt_dir),
         "--delay-s", str(args.delay_s)],
        env={**os.environ,
             "PYTHONPATH": str(pathlib.Path(__file__).resolve()
                               .parent.parent / "src")})
    deadline = time.monotonic() + 120.0
    try:
        while len(_snapshots(ckpt_dir)) < args.kill_after:
            if child.poll() is not None:
                raise SystemExit(
                    f"child exited early (rc={child.returncode}) with "
                    f"{len(_snapshots(ckpt_dir))} snapshots")
            if time.monotonic() > deadline:
                raise SystemExit("timed out waiting for child snapshots")
            time.sleep(0.02)
    finally:
        if child.poll() is None:
            child.send_signal(signal.SIGKILL)
        child.wait()

    killed_at = _snapshots(ckpt_dir)
    res = resume_sweep(wl, _space, checkpoint_dir=str(ckpt_dir),
                       checkpoint_every=4, chunk_size=CHUNK,
                       backend="numpy")

    failures: list[str] = []
    if res.n_configs != ref.n_configs:
        failures.append(f"n_configs {res.n_configs} != {ref.n_configs}")
    if res.n_chunks != ref.n_chunks:
        failures.append(f"n_chunks {res.n_chunks} != {ref.n_chunks}")
    front_identical = res.front_size == ref.front_size and all(
        np.array_equal(res.front_metrics[m], ref.front_metrics[m])
        for m in ref.front_metrics) and all(
        np.array_equal(res.front_soa[k], ref.front_soa[k])
        for k in ref.front_soa)
    if not front_identical:
        failures.append("resumed front differs from uninterrupted run")

    # the killed child's JSONL event log must replay: every complete line
    # parses (a torn final line is tolerated) and carries the sweep's
    # stage spans up to the kill point
    from repro.obs import load_jsonl
    jsonl_path = ckpt_dir / "trace.jsonl"
    replayed: list[dict] = []
    if not jsonl_path.is_file():
        failures.append("killed child left no trace.jsonl")
    else:
        replayed = load_jsonl(jsonl_path)
        names = {s.get("name") for s in replayed}
        if "sweep.synthesize" not in names or "sweep.reduce" not in names:
            failures.append(
                f"replayed JSONL lacks sweep stage spans (got {names})")
        bad = [s for s in replayed
               if not isinstance(s.get("dur_s"), (int, float))]
        if bad:
            failures.append(
                f"{len(bad)} replayed spans missing numeric dur_s")

    r = {
        "provenance": provenance(),
        "n_configs": ref.n_configs,
        "n_chunks": ref.n_chunks,
        "child_killed_with_snapshots": len(killed_at),
        "child_returncode": child.returncode,
        "resumed_front_size": res.front_size,
        "reference_front_size": ref.front_size,
        "front_identical_after_sigkill_resume": front_identical,
        "jsonl_spans_replayed_after_sigkill": len(replayed),
    }
    for k, v in sorted(r.items()):
        if k != "provenance":
            print(f"{k}: {v}")
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")
    print(f"wrote {args.out}")
    if failures:
        raise SystemExit("preempt/resume smoke FAILED:\n  "
                         + "\n  ".join(failures))
    print("preempt/resume smoke OK: SIGKILL mid-stream, front bit-identical")


if __name__ == "__main__":
    main()
