"""Kernel micro-benchmarks (CPU reference path timings + derived rates).

On this CPU container the Pallas kernels run in interpret mode (for
correctness only); the timed numbers here are the jnp reference path —
the production numbers come from the dry-run roofline (§Roofline).
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.quant import quantizers as qz


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    m = k = n = 512
    x = jax.random.normal(jax.random.key(0), (m, k))
    w = jax.random.normal(jax.random.key(1), (k, n))
    xs = qz.int_scale(x, 8)
    xq = qz.quantize_int(x, xs, 8)
    ws8 = qz.int_scale(w, 8, axis=0)
    wq8 = qz.quantize_int(w, ws8, 8)
    flops = 2 * m * k * n

    us = _time(lambda a, b: ops.w8a8_matmul(a, b, xs, ws8, impl="ref"),
               xq, wq8)
    rows.append(("kernel/w8a8_ref_512", us,
                 f"GFLOPs={flops / us / 1e3:.1f}"))

    wsp = qz.pow2_scale(w, axis=0)
    packed = qz.pack_int4(qz.pow2_encode(w, wsp).T).T
    us = _time(lambda a, b: ops.w4a8_matmul(a, b, xs, wsp, impl="ref"),
               xq, packed)
    rows.append(("kernel/w4a8_ref_512", us,
                 f"GFLOPs={flops / us / 1e3:.1f}"))

    b, h, s, d = 1, 4, 512, 64
    q = jax.random.normal(jax.random.key(2), (b, h, s, d))
    kk = jax.random.normal(jax.random.key(3), (b, h, s, d))
    v = jax.random.normal(jax.random.key(4), (b, h, s, d))
    aflops = 4 * b * h * s * s * d
    us = _time(lambda a, b_, c: ops.flash_attention(a, b_, c, impl="ref"),
               q, kk, v)
    rows.append(("kernel/attention_ref_512", us,
                 f"GFLOPs={aflops / us / 1e3:.1f}"))

    # interpret-mode pallas (correctness path) on a small shape
    t0 = time.perf_counter()
    ops.w8a8_matmul(xq[:64, :64], wq8[:64, :64], xs, ws8[:, :64],
                    impl="interpret", bm=32, bn=32, bk=32).block_until_ready()
    rows.append(("kernel/w8a8_pallas_interpret_64",
                 (time.perf_counter() - t0) * 1e6, "validation_path"))

    # the batched DSE array kernel (configs x layers in fused numpy ops)
    from repro.core.accelerator import design_space
    from repro.core.dse_batch import sweep_workload
    from repro.core.synthesis import synthesize_many
    from repro.core.workloads import get_workload
    cfgs = tuple(design_space())
    wl = get_workload("vgg16")
    reports = synthesize_many(cfgs)        # exclude synthesis: mapping only
    sweep_workload(wl, cfgs, reports)      # warm
    t0 = time.perf_counter()
    iters = 10
    for _ in range(iters):
        sweep_workload(wl, cfgs, reports)
    us = (time.perf_counter() - t0) / iters * 1e6
    rows.append(("kernel/dse_batched_map_720cfg", us,
                 f"configs_per_s={len(cfgs) / us * 1e6:.0f}"))
    return rows
