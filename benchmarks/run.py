# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows: Fig. 2 (PPA model accuracy), Figs. 3-5 (DSE Pareto + headline
# ratios), kernel micro-benches, and the §Roofline table from the dry-run.
import sys
import traceback


def main() -> None:
    from benchmarks import (accuracy_bench, coexplore_bench,
                            coexplore_many_bench, dse_sweep_bench,
                            fig2_ppa_accuracy, fig3to5_dse, kernel_bench,
                            quant_accuracy, roofline_bench,
                            serving_dse_bench)
    modules = [
        ("fig2", fig2_ppa_accuracy),
        ("fig3to5", fig3to5_dse),
        ("dse_sweep", dse_sweep_bench),
        ("coexplore", coexplore_bench),
        ("coexplore_many", coexplore_many_bench),
        ("serving_dse", serving_dse_bench),
        ("accuracy", accuracy_bench),
        ("kernels", kernel_bench),
        ("quant_acc", quant_accuracy),
        ("roofline", roofline_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for tag, mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:
            failures += 1
            print(f"{tag}/EXCEPTION,0.00,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    # end-of-run telemetry: accumulated registry counters across every
    # bench above (cache hit rates, configs/s, evals/s) — stderr so the
    # CSV on stdout stays machine-parseable
    from repro.obs import render_text
    print(render_text(), file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
