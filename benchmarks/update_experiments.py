"""Swap the §Dry-run / §Roofline tables in EXPERIMENTS.md for the ones
generated from the current DRYRUN_DIR (default: newest dryrun_v*)."""

import io
import re
import sys
from contextlib import redirect_stdout

from benchmarks.make_experiments_tables import (dryrun_table, load,
                                                roofline_table, summary)


def _capture(fn, *a, **k):
    buf = io.StringIO()
    with redirect_stdout(buf):
        fn(*a, **k)
    return buf.getvalue().strip()


def main():
    recs = load()
    dry = _capture(dryrun_table, recs)
    r1 = _capture(roofline_table, recs, "16x16")
    r2 = _capture(roofline_table, recs, "2x16x16")
    summ = _capture(summary, recs).splitlines()[0]

    text = open("EXPERIMENTS.md").read()

    def swap_table(text, anchor, new_table):
        """Replace the first markdown table after ``anchor``."""
        i = text.index(anchor)
        m = re.search(r"\n\|[^\n]*\n\|[-| ]*\n(?:\|[^\n]*\n)+",
                      text[i:])
        start, end = i + m.start() + 1, i + m.end()
        return text[:start] + new_table + "\n" + text[end:]

    text = swap_table(text, "## §Dry-run", dry)
    text = re.sub(r"Summary: cells:[^\n]*", f"Summary: {summ}", text, 1)
    text = swap_table(text, "### Single-pod 16×16", r1)
    text = swap_table(text, "### Multi-pod 2×16×16", r2)
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md tables updated;", summ)


if __name__ == "__main__":
    main()
