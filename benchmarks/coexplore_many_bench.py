"""Multi-workload co-exploration benchmark: guided (NSGA-II + external
archive) vs random search at equal evaluation budget over the joint
(shared hardware config x per-workload, per-layer precision) space — the
full QUIDAM setting over the paper's three workloads.

Measures evaluation throughput (genomes/s through the fused W-workload
kernel `sweep_mixed_many`), the hypervolume each method reaches under one
shared reference point, the synthesis-cache hit rate the shared-hardware
genome encoding achieves (one synthesis pass serves all W workloads per
hardware config), and whether the NSGA-II external archive supersets the
final population's non-dominated set.  Emits
``BENCH_coexplore_many.json`` so the trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/coexplore_many_bench.py [--quick]
      [--workloads vgg16 resnet34 resnet50]
      [--out BENCH_coexplore_many.json]
      [--check-against BENCH_coexplore_many.json]

``--quick`` is the CI smoke mode.  ``--check-against`` fails on a >3x
evals/s regression vs the committed baseline; the guided >= random
hypervolume requirement and the archive-superset invariant are always
enforced, and full runs additionally require a synthesis-cache hit rate
>= 80%.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

from repro.core.dse import coexplore_many  # noqa: E402
from repro.core.dse_batch import resolve_backend  # noqa: E402
from repro.core.synthesis import (clear_synthesis_cache,  # noqa: E402
                                  synthesis_cache_stats)
from repro.explore.pareto import (hypervolume, pareto_mask_k,  # noqa: E402
                                  reference_point)

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_coexplore_many.json"
MIN_HIT_RATE = 0.80


def bench_method(method: str, workloads, budget: int, seed: int,
                 backend: str, **kwargs) -> tuple[dict, object]:
    clear_synthesis_cache()
    t0 = time.perf_counter()
    res = coexplore_many(workloads, preset="many-default", method=method,
                         budget=budget, seed=seed, backend=backend,
                         **kwargs)
    dt = time.perf_counter() - t0
    stats = synthesis_cache_stats()
    hits, misses = stats["array_hits"], stats["array_misses"]
    return {
        f"{method}_s": dt,
        f"{method}_evals_per_s": res.n_evals / dt,
        f"{method}_front_size": res.front_size,
        f"{method}_kernel_evals": res.stats["kernel_evals"],
        f"{method}_memo_hits": res.stats["memo_hits"],
        f"{method}_synth_cache_hits": hits,
        f"{method}_synth_cache_misses": misses,
        f"{method}_synth_cache_hit_rate": hits / max(1, hits + misses),
        f"{method}_history": [[int(e), float(h)] for e, h in res.history],
    }, res


def _archive_supersets_population(res) -> bool:
    """The archive is a superset of the final population's non-dominated
    set: judging dominance over archive ∪ population (a population member
    beaten by an archived genome from an earlier generation *is*
    dominated), every surviving genome already sits in the archive —
    i.e. the population adds nothing the archive lost."""
    if res.population is None:
        return False
    comb_g = np.concatenate([res.genomes, res.population])
    comb_F = np.concatenate([res.front_objectives,
                             res.population_objectives])
    keep = pareto_mask_k(comb_F)
    return all((res.genomes == row).all(axis=1).any()
               for row in comb_g[keep])


def bench(workloads=("vgg16", "resnet34", "resnet50"), quick: bool = False,
          seed: int = 0, with_jax: bool = True) -> dict:
    budget = 384 if quick else 3072
    pop = 24 if quick else 64
    backends = ["numpy"]
    if with_jax:
        try:
            resolve_backend("jax")
            backends.append("jax")
        except RuntimeError:
            pass

    out: dict = {
        "workloads": list(workloads), "quick": quick, "seed": seed,
        "budget": budget, "pop_size": pop,
        "provenance": provenance(),
    }
    rows_r, res_r = bench_method("random", workloads, budget, seed,
                                 "numpy")
    rows_n, res_n = bench_method("nsga2", workloads, budget, seed,
                                 "numpy", pop_size=pop)
    out.update(rows_r)
    out.update(rows_n)
    out["archive_supersets_population_front"] = \
        _archive_supersets_population(res_n)

    # one shared reference point -> comparable hypervolumes
    ref = reference_point(np.concatenate([res_r.all_objectives,
                                          res_n.all_objectives]))
    hv_r = hypervolume(res_r.front_objectives, ref)
    hv_n = hypervolume(res_n.front_objectives, ref)
    out.update(
        shared_ref_point=[float(x) for x in ref],
        random_hypervolume=hv_r,
        nsga2_hypervolume=hv_n,
        nsga2_vs_random_hypervolume=hv_n / max(hv_r, 1e-300),
        guided_beats_random=bool(hv_n >= hv_r),
    )

    if "jax" in backends:
        rows_j, res_j = bench_method("nsga2", workloads, budget, seed,
                                     "jax", pop_size=pop)
        out["nsga2_jax_evals_per_s"] = rows_j["nsga2_evals_per_s"]
        out["nsga2_jax_s"] = rows_j["nsga2_s"]

        def _row_sorted(g):
            return g[np.lexsort(g.T[::-1])]

        same_front = (res_j.genomes.shape == res_n.genomes.shape
                      and bool(np.array_equal(_row_sorted(res_j.genomes),
                                              _row_sorted(res_n.genomes))))
        out["nsga2_jax_front_matches_numpy"] = same_front

        # sharded evaluation: the same search with every population chunk
        # spread across all devices via shard_map (1 device degenerates to
        # the plain jit path — the multi-device-smoke CI job runs this
        # with 4 forced host devices)
        import jax
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh()
        rows_s, res_s = bench_method("nsga2", workloads, budget, seed,
                                     "jax", pop_size=pop, mesh=mesh)
        out["n_devices"] = jax.device_count()
        out["nsga2_jax_sharded_evals_per_s"] = rows_s["nsga2_evals_per_s"]
        out["nsga2_jax_sharded_s"] = rows_s["nsga2_s"]
        out["nsga2_jax_sharded_front_matches_numpy"] = (
            res_s.genomes.shape == res_n.genomes.shape
            and bool(np.array_equal(_row_sorted(res_s.genomes),
                                    _row_sorted(res_n.genomes))))

    if not quick:
        # quick-mode numbers recorded by full runs keep the CI regression
        # gate like-for-like (see check_against)
        q = bench(workloads=workloads, quick=True, seed=seed,
                  with_jax=False)
        out["quick_nsga2_evals_per_s"] = q["nsga2_evals_per_s"]
        out["quick_random_evals_per_s"] = q["random_evals_per_s"]
    return out


def check_against(r: dict, baseline_path: pathlib.Path) -> None:
    """CI gate: >3x evals/s regression vs the committed baseline fails
    (same pattern as the sweep benches)."""
    base = json.loads(baseline_path.read_text())
    if r["quick"] and "quick_nsga2_evals_per_s" in base:
        base_eps = base["quick_nsga2_evals_per_s"]
        label = "quick baseline"
    else:
        base_eps = base["nsga2_evals_per_s"]
        label = "baseline"
    got = r["nsga2_evals_per_s"]
    print(f"regression check: nsga2 {got:.0f} evals/s vs {label} "
          f"{base_eps:.0f} (floor {base_eps / 3:.0f})")
    if got * 3.0 < base_eps:
        raise SystemExit(
            f"multi-workload co-exploration regressed >3x: {got:.0f} "
            f"evals/s vs {label} {base_eps:.0f}")


def run():
    """benchmarks/run.py entry: CSV rows (name, us_per_call, derived)."""
    r = bench(quick=True)
    return [
        ("coexplore_many/random", 1e6 / r["random_evals_per_s"],
         f"evals_per_s={r['random_evals_per_s']:.0f}"),
        ("coexplore_many/nsga2", 1e6 / r["nsga2_evals_per_s"],
         f"evals_per_s={r['nsga2_evals_per_s']:.0f}"),
        ("coexplore_many/hv_ratio", 0.0,
         f"{r['nsga2_vs_random_hypervolume']:.3f}"),
        ("coexplore_many/cache_hit_rate", 0.0,
         f"{r['nsga2_synth_cache_hit_rate']:.3f}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced budget (CI smoke mode)")
    ap.add_argument("--workloads", nargs="+",
                    default=["vgg16", "resnet34", "resnet50"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--check-against", type=pathlib.Path, default=None,
                    help="baseline BENCH json; fail on >3x regression")
    args = ap.parse_args()

    r = bench(workloads=tuple(args.workloads), quick=args.quick,
              seed=args.seed)
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")

    print(f"workloads: {'+'.join(r['workloads'])}  budget: {r['budget']} "
          f"evals{'  (quick)' if r['quick'] else ''}")
    for m in ("random", "nsga2"):
        print(f"{m:6s}  {r[f'{m}_s'] * 1e3:8.1f} ms  "
              f"{r[f'{m}_evals_per_s']:9.0f} evals/s  "
              f"front={r[f'{m}_front_size']}  "
              f"cache hit rate={r[f'{m}_synth_cache_hit_rate']:.1%}")
    if "nsga2_jax_evals_per_s" in r:
        print(f"nsga2 (jax) {r['nsga2_jax_s'] * 1e3:6.1f} ms  "
              f"{r['nsga2_jax_evals_per_s']:9.0f} evals/s  "
              f"front matches numpy: "
              f"{r['nsga2_jax_front_matches_numpy']}")
    if "nsga2_jax_sharded_evals_per_s" in r:
        print(f"nsga2 (jax, {r['n_devices']}-device mesh) "
              f"{r['nsga2_jax_sharded_s'] * 1e3:6.1f} ms  "
              f"{r['nsga2_jax_sharded_evals_per_s']:9.0f} evals/s  "
              f"front matches numpy: "
              f"{r['nsga2_jax_sharded_front_matches_numpy']}")
    print(f"hypervolume (shared ref): nsga2 {r['nsga2_hypervolume']:.5g} "
          f"vs random {r['random_hypervolume']:.5g}  "
          f"({r['nsga2_vs_random_hypervolume']:.3f}x)")
    print(f"archive supersets population front: "
          f"{r['archive_supersets_population_front']}")
    print(f"wrote {args.out}")

    if args.check_against is not None:
        check_against(r, args.check_against)
    if not r["guided_beats_random"]:
        raise SystemExit(
            "guided search fell below the random baseline hypervolume: "
            f"{r['nsga2_hypervolume']:.5g} < {r['random_hypervolume']:.5g}")
    if not r["archive_supersets_population_front"]:
        raise SystemExit(
            "NSGA-II external archive dropped a non-dominated genome from "
            "the final population")
    if not r.get("nsga2_jax_sharded_front_matches_numpy", True):
        raise SystemExit(
            f"mesh-sharded nsga2 front diverged from the numpy front "
            f"({r.get('n_devices')} device(s))")
    if not r["quick"] and r["nsga2_synth_cache_hit_rate"] < MIN_HIT_RATE:
        raise SystemExit(
            f"synthesis-cache hit rate "
            f"{r['nsga2_synth_cache_hit_rate']:.1%} < "
            f"{MIN_HIT_RATE:.0%}: the shared-hardware genome encoding is "
            f"no longer reusing synthesis across workloads")


if __name__ == "__main__":
    main()
