"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.make_experiments_tables
prints the §Dry-run and §Roofline markdown tables.
"""

import glob
import json
import os

DIR = os.environ.get(
    "DRYRUN_DIR",
    "experiments/dryrun_v3" if os.path.isdir("experiments/dryrun_v3")
    else "experiments/dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "moonshot-v1-16b-a3b", "phi3.5-moe-42b-a6.6b", "mamba2-130m",
    "starcoder2-7b", "phi4-mini-3.8b", "deepseek-67b", "gemma3-4b",
    "llama-3.2-vision-90b", "whisper-medium", "zamba2-1.2b",
]


def _fmt_bytes(n):
    return f"{n / 2**30:.2f}"


def load(quant=False):
    recs = {}
    for f in glob.glob(os.path.join(DIR, "*.json")):
        r = json.load(open(f))
        is_q = f.endswith("__quant.json")
        if is_q != quant:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs, mesh="16x16"):
    print("| arch | shape | bottleneck | compute s | memory s | collective s"
          " | MODEL_FLOPS | useful (6ND/HLO) | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                continue
            if r["status"] == "skipped":
                print(f"| {arch} | {shape} | — skipped (sub-quadratic attn"
                      f" required) | | | | | | |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            ro = r["roofline"]
            print(f"| {arch} | {shape} | **{ro['bottleneck']}** |"
                  f" {ro['compute_s']:.3f} | {ro['memory_s']:.3f} |"
                  f" {ro['collective_s']:.3f} | {ro['model_flops']:.2e} |"
                  f" {ro['useful_flops_ratio']:.2f} |"
                  f" {ro['roofline_fraction']:.4f} |")


def dryrun_table(recs):
    print("| arch | shape | mesh | per-dev args GiB | per-dev temp GiB |"
          " HLO GFLOPs/dev | coll GiB/dev | AR/AG/RS/A2A/CP counts |"
          " compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None or r["status"] != "ok":
                    continue
                s, m = r["stats"], r["memory_analysis"]
                c = s["collective_count_by_kind"]
                counts = "/".join(str(c.get(k, 0)) for k in (
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"))
                print(f"| {arch} | {shape} | {mesh} |"
                      f" {_fmt_bytes(m['argument_bytes'])} |"
                      f" {_fmt_bytes(m['temp_bytes'])} |"
                      f" {s['flops'] / 1e9:.0f} |"
                      f" {_fmt_bytes(s['collective_bytes'])} |"
                      f" {counts} | {r.get('compile_s', 0)} |")


def summary(recs):
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"cells: ok={ok} skipped={sk} error={er}")
    worst = sorted((r for r in recs.values() if r["status"] == "ok"),
                   key=lambda r: r["roofline"]["roofline_fraction"])
    coll = sorted((r for r in recs.values() if r["status"] == "ok"),
                  key=lambda r: -(r["roofline"]["collective_s"]
                                  / max(r["roofline"]["step_time_s"],
                                        1e-12)))
    print("worst roofline fraction:",
          [(r["arch"], r["shape"], r["mesh"],
            round(r["roofline"]["roofline_fraction"], 4))
           for r in worst[:6]])
    print("most collective-bound:",
          [(r["arch"], r["shape"], r["mesh"],
            round(r["roofline"]["collective_s"]
                  / max(r["roofline"]["step_time_s"], 1e-12), 3))
           for r in coll[:6]])


if __name__ == "__main__":
    recs = load()
    print("## Dry-run table\n")
    dryrun_table(recs)
    print("\n## Roofline (single-pod 16x16)\n")
    roofline_table(recs, "16x16")
    print("\n## Roofline (multi-pod 2x16x16)\n")
    roofline_table(recs, "2x16x16")
    print()
    summary(recs)
