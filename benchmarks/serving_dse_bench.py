"""Serving-fleet DSE benchmark: traffic-aware objectives vs per-inference
EDP objectives at equal search budget.

For each traffic preset, runs the guided co-exploration engine under the
serving objective set (p99 latency under SLO, energy per served token,
quantization noise) and under the per-inference EDP set, then reports:

* evaluation throughput (genomes/s through the fused kernel + fleet sim),
* the *front shift*: whether the serving-fleet Pareto front selects a
  different genome set than the per-inference front (the paper-level
  claim that queueing pressure changes which designs win),
* numpy vs jax front parity (<= 1e-6 on objectives, identical genomes),
* raw fleet-simulator throughput (candidate-traces/s).

Emits ``BENCH_serving_dse.json`` so the trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/serving_dse_bench.py [--quick]
      [--workload vgg16] [--out BENCH_serving_dse.json]
      [--check-against BENCH_serving_dse.json]

``--quick`` is the CI smoke mode.  ``--check-against`` fails on a >3x
evals/s regression vs the committed baseline; the front-shift
requirement (serving front != EDP front on >= 1 preset) is always
enforced.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

from repro.core.dse import ExploreSpec, run as run_spec  # noqa: E402
from repro.core.dse_batch import resolve_backend  # noqa: E402
from repro.core.synthesis import clear_synthesis_cache  # noqa: E402
from repro.serving.fleet_sim import simulate_fleet  # noqa: E402
from repro.serving.traffic import make_trace  # noqa: E402

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_serving_dse.json"

SERVING_OBJS = ("p99_latency_s", "energy_per_token_j", "accuracy_noise")
EDP_OBJS = ("edp", "accuracy_noise")


def _genome_set(res) -> set:
    return {g.tobytes() for g in res.genomes}


def _campaign(workload: str, budget: int, pop: int, seed: int,
              backend: str, *, traffic: str | None,
              objectives) -> tuple[object, float]:
    clear_synthesis_cache()
    t0 = time.perf_counter()
    res = run_spec(ExploreSpec.mixed(
        workload, preset="quick", budget=budget, seed=seed,
        backend=backend, objectives=objectives, traffic=traffic,
        pop_size=pop))
    return res, time.perf_counter() - t0


def bench_fleet_sim(n_candidates: int = 256, preset: str = "steady") -> dict:
    """Raw simulator throughput over an (N, R) grid."""
    rng = np.random.default_rng(0)
    step = rng.uniform(0.02, 0.9, n_candidates)
    etok = rng.uniform(0.3, 3.0, n_candidates)
    trace = make_trace(preset)
    simulate_fleet(step[:2], etok[:2], trace)          # warm-up
    t0 = time.perf_counter()
    res = simulate_fleet(step, etok, trace)
    dt = time.perf_counter() - t0
    return {
        "fleet_sim_candidates": n_candidates,
        "fleet_sim_requests": trace.n_requests,
        "fleet_sim_s": dt,
        "fleet_sim_candidates_per_s": n_candidates / dt,
        "fleet_sim_horizon_iters": res.n_iters,
    }


def bench(workload: str = "vgg16", quick: bool = False,
          seed: int = 0, with_jax: bool = True) -> dict:
    budget = 256 if quick else 1024
    pop = 24 if quick else 48
    presets = ["quick"] if quick else ["steady", "bursty", "interactive"]
    jax_ok = False
    if with_jax:
        try:
            resolve_backend("jax")
            jax_ok = True
        except RuntimeError:
            pass

    out: dict = {
        "workload": workload, "quick": quick, "seed": seed,
        "budget": budget, "pop_size": pop, "presets": presets,
        "provenance": provenance(),
    }
    out.update(bench_fleet_sim(n_candidates=64 if quick else 256))

    # the per-inference EDP baseline front, shared across presets
    res_edp, dt_edp = _campaign(workload, budget, pop, seed, "numpy",
                                traffic=None, objectives=EDP_OBJS)
    out["edp_evals_per_s"] = res_edp.n_evals / dt_edp
    out["edp_front_size"] = res_edp.front_size
    edp_genomes = _genome_set(res_edp)

    shifted = []
    for preset in presets:
        res_s, dt_s = _campaign(workload, budget, pop, seed, "numpy",
                                traffic=preset, objectives=SERVING_OBJS)
        shift = _genome_set(res_s) != edp_genomes
        shifted.append(shift)
        out[f"{preset}_evals_per_s"] = res_s.n_evals / dt_s
        out[f"{preset}_front_size"] = res_s.front_size
        out[f"{preset}_front_shifted_vs_edp"] = bool(shift)
        if preset == presets[0]:
            out["serving_evals_per_s"] = out[f"{preset}_evals_per_s"]
            if jax_ok:
                res_j, dt_j = _campaign(workload, budget, pop, seed,
                                        "jax", traffic=preset,
                                        objectives=SERVING_OBJS)
                out["serving_jax_evals_per_s"] = res_j.n_evals / dt_j
                same = (res_j.genomes.shape == res_s.genomes.shape
                        and bool(np.array_equal(
                            np.sort(res_j.genomes, axis=0),
                            np.sort(res_s.genomes, axis=0))))
                a, b = res_s.front_objectives, res_j.front_objectives
                if same and a.shape == b.shape:
                    denom = np.where(a == 0, 1.0, a)
                    rel = float(np.max(np.abs(b / denom - 1.0))) \
                        if a.size else 0.0
                else:
                    rel = float("inf")
                out["serving_jax_front_matches_numpy"] = same
                out["serving_jax_front_rel_err"] = rel

    out["front_shift_presets"] = int(sum(shifted))
    out["front_shift_claim"] = bool(any(shifted))

    if not quick:
        # quick-mode numbers recorded by full runs keep the CI regression
        # gate like-for-like (see check_against)
        q = bench(workload=workload, quick=True, seed=seed,
                  with_jax=False)
        out["quick_serving_evals_per_s"] = q["serving_evals_per_s"]
        out["quick_edp_evals_per_s"] = q["edp_evals_per_s"]
    return out


def check_against(r: dict, baseline_path: pathlib.Path) -> None:
    """CI gate: >3x serving evals/s regression vs the committed baseline
    fails (same pattern as the sweep/coexplore benches)."""
    base = json.loads(baseline_path.read_text())
    if r["quick"] and "quick_serving_evals_per_s" in base:
        base_eps = base["quick_serving_evals_per_s"]
        label = "quick baseline"
    else:
        base_eps = base["serving_evals_per_s"]
        label = "baseline"
    got = r["serving_evals_per_s"]
    print(f"regression check: serving {got:.0f} evals/s vs {label} "
          f"{base_eps:.0f} (floor {base_eps / 3:.0f})")
    if got * 3.0 < base_eps:
        raise SystemExit(
            f"serving DSE regressed >3x: {got:.0f} evals/s vs "
            f"{label} {base_eps:.0f}")


def run():
    """benchmarks/run.py entry: CSV rows (name, us_per_call, derived)."""
    r = bench(quick=True)
    return [
        ("serving/nsga2", 1e6 / r["serving_evals_per_s"],
         f"evals_per_s={r['serving_evals_per_s']:.0f}"),
        ("serving/edp_baseline", 1e6 / r["edp_evals_per_s"],
         f"evals_per_s={r['edp_evals_per_s']:.0f}"),
        ("serving/fleet_sim", 1e6 / r["fleet_sim_candidates_per_s"],
         f"candidates_per_s={r['fleet_sim_candidates_per_s']:.0f}"),
        ("serving/front_shift", 0.0,
         f"presets_shifted={r['front_shift_presets']}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced budget (CI smoke mode)")
    ap.add_argument("--workload", default="vgg16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--check-against", type=pathlib.Path, default=None,
                    help="baseline BENCH json; fail on >3x regression")
    args = ap.parse_args()

    r = bench(workload=args.workload, quick=args.quick, seed=args.seed)
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")

    print(f"workload: {r['workload']}  budget: {r['budget']} evals"
          f"{'  (quick)' if r['quick'] else ''}")
    print(f"fleet sim: {r['fleet_sim_candidates_per_s']:.0f} candidate-"
          f"traces/s over {r['fleet_sim_horizon_iters']} iterations")
    print(f"edp baseline: {r['edp_evals_per_s']:.0f} evals/s  "
          f"front={r['edp_front_size']}")
    for preset in r["presets"]:
        print(f"{preset:12s} {r[f'{preset}_evals_per_s']:9.0f} evals/s  "
              f"front={r[f'{preset}_front_size']}  "
              f"shifted={r[f'{preset}_front_shifted_vs_edp']}")
    if "serving_jax_front_matches_numpy" in r:
        print(f"jax parity: genomes match={r['serving_jax_front_matches_numpy']}  "
              f"rel err={r['serving_jax_front_rel_err']:.2g}")
    print(f"wrote {args.out}")

    if args.check_against is not None:
        check_against(r, args.check_against)
    if not r["front_shift_claim"]:
        raise SystemExit(
            "serving-fleet front matched the per-inference EDP front on "
            "every preset — traffic-aware objectives made no difference")
    if r.get("serving_jax_front_rel_err", 0.0) > 1e-6:
        raise SystemExit(
            f"numpy/jax serving front parity broke: rel err "
            f"{r['serving_jax_front_rel_err']:.3g} > 1e-6")


if __name__ == "__main__":
    main()
