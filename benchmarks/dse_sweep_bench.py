"""DSE sweep engine benchmark: scalar loop vs vectorized batched engine.

Times `explore()` over the full paper design space on a paper workload with
both engines, checks the headline ratios are identical, and emits
``BENCH_dse_sweep.json`` (configs/sec + speedups) so the perf trajectory is
tracked across PRs.

  PYTHONPATH=src python benchmarks/dse_sweep_bench.py [--quick]
      [--workload vgg16] [--out BENCH_dse_sweep.json]

``--quick`` shrinks the design space and repetitions — the CI smoke mode
that exercises the engine without holding the queue.
"""

from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import time

from repro.core.accelerator import design_space
from repro.core.dse import explore, explore_many, explore_scalar
from repro.core.synthesis import clear_synthesis_cache, synthesis_cache_stats

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_dse_sweep.json"


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench(workload: str = "vgg16", quick: bool = False) -> dict:
    configs = list(design_space())
    if quick:
        configs = list(itertools.islice(configs, 0, None, 4))  # every 4th
    n = len(configs)
    reps_scalar = 1 if quick else 3
    reps_batched = 3 if quick else 10

    scalar_s = _best_of(lambda: explore_scalar(workload, configs),
                        reps_scalar)

    def cold():
        clear_synthesis_cache()
        explore(workload, configs)

    cold_s = _best_of(cold, reps_batched)
    warm_s = _best_of(lambda: explore(workload, configs), reps_batched)

    # identical results is part of the contract, not just speed
    r_scalar = explore_scalar(workload, configs).headline_ratios()
    r_batched = explore(workload, configs).headline_ratios()
    identical = r_scalar == r_batched

    # multi-workload amortization: one synthesis pass, three mapping passes
    wls = ("vgg16", "resnet34", "resnet50")
    clear_synthesis_cache()
    t0 = time.perf_counter()
    explore_many(wls, configs)
    many_s = time.perf_counter() - t0

    return {
        "workload": workload,
        "quick": quick,
        "n_configs": n,
        "scalar_s": scalar_s,
        "scalar_configs_per_s": n / scalar_s,
        "batched_cold_s": cold_s,
        "batched_cold_configs_per_s": n / cold_s,
        "batched_warm_s": warm_s,
        "batched_warm_configs_per_s": n / warm_s,
        "speedup_cold": scalar_s / cold_s,
        "speedup_warm": scalar_s / warm_s,
        "explore_many_3wl_s": many_s,
        "explore_many_configs_per_s": 3 * n / many_s,
        "headline_ratios_identical": identical,
        "synthesis_cache": synthesis_cache_stats(),
    }


def run():
    """benchmarks/run.py entry: CSV rows (name, us_per_call, derived)."""
    r = bench(quick=True)
    n = r["n_configs"]
    return [
        ("dse_sweep/scalar", r["scalar_s"] / n * 1e6,
         f"configs_per_s={r['scalar_configs_per_s']:.0f}"),
        ("dse_sweep/batched_cold", r["batched_cold_s"] / n * 1e6,
         f"speedup={r['speedup_cold']:.1f}x"),
        ("dse_sweep/batched_warm", r["batched_warm_s"] / n * 1e6,
         f"speedup={r['speedup_warm']:.1f}x"),
        ("dse_sweep/identical", 0.0,
         str(r["headline_ratios_identical"])),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced space + reps (CI smoke mode)")
    ap.add_argument("--workload", default="vgg16")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    r = bench(workload=args.workload, quick=args.quick)
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")

    print(f"design points: {r['n_configs']}  workload: {r['workload']}"
          f"{'  (quick)' if r['quick'] else ''}")
    print(f"scalar        {r['scalar_s'] * 1e3:8.1f} ms  "
          f"{r['scalar_configs_per_s']:9.0f} configs/s")
    print(f"batched cold  {r['batched_cold_s'] * 1e3:8.1f} ms  "
          f"{r['batched_cold_configs_per_s']:9.0f} configs/s  "
          f"({r['speedup_cold']:.1f}x)")
    print(f"batched warm  {r['batched_warm_s'] * 1e3:8.1f} ms  "
          f"{r['batched_warm_configs_per_s']:9.0f} configs/s  "
          f"({r['speedup_warm']:.1f}x)")
    print(f"explore_many  {r['explore_many_3wl_s'] * 1e3:8.1f} ms  "
          f"3 workloads, {r['explore_many_configs_per_s']:.0f} configs/s")
    print(f"headline ratios identical: {r['headline_ratios_identical']}")
    print(f"wrote {args.out}")
    if not r["headline_ratios_identical"]:
        raise SystemExit("batched engine diverged from scalar reference")
    if not r["quick"] and r["speedup_cold"] < 10.0:
        raise SystemExit(
            f"speedup gate failed: {r['speedup_cold']:.1f}x < 10x")


if __name__ == "__main__":
    main()
