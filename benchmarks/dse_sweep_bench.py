"""DSE sweep engine benchmark: scalar loop vs vectorized batched engine
vs the streamed chunked driver.

Times `explore()` over the full paper design space on a paper workload
with both engines, exercises the x64-free jax jit path and the 100k-config
chunked stream, checks the headline ratios are identical, and emits
``BENCH_dse_sweep.json`` (configs/sec + speedups + provenance) so the perf
trajectory is tracked across PRs and machines.

  PYTHONPATH=src python benchmarks/dse_sweep_bench.py [--quick]
      [--workload vgg16] [--out BENCH_dse_sweep.json]
      [--check-against BENCH_dse_sweep.json]

``--quick`` shrinks the design space and repetitions — the CI smoke mode
that exercises the engine without holding the queue.  ``--check-against``
compares the measured cold throughput to a committed baseline and fails
on a >3x regression.
"""

from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import platform
import subprocess
import sys
import time

import numpy as np

from repro.core.accelerator import design_space, design_space_soa
from repro.core.dse import explore, explore_many, explore_scalar
from repro.core.dse_batch import resolve_backend, sweep_chunked
from repro.core.synthesis import clear_synthesis_cache, synthesis_cache_stats
from repro.core.workloads import get_workload

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_dse_sweep.json"

# widened factor grid for the chunked-scaling entry (~103k configs full,
# ~15k quick); everything else stays the paper's 720-point space
_CHUNKED_FULL = dict(glb_kbs=tuple(2 ** i for i in range(2, 13)),
                     bws=tuple(np.linspace(2.0, 64.0, 156)))
_CHUNKED_QUICK = dict(glb_kbs=(64, 128, 256, 512),
                      bws=tuple(np.linspace(2.0, 64.0, 64)))


def _best_of(fn, reps: int) -> float:
    import gc
    best = float("inf")
    was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()                 # keep collector pauses out of the timings
    try:
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()
    return best


def provenance() -> dict:
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=pathlib.Path(__file__).parent
                             ).stdout.strip() or None
    except Exception:
        sha = None
    try:
        import jax
        jax_version = jax.__version__
        n_devices = jax.device_count()
    except Exception:
        jax_version = None
        n_devices = None
    import os
    from repro.obs import snapshot as obs_snapshot
    return {
        "python": platform.python_version(),
        "numpy": np.__version__,
        "jax": jax_version,
        "jax_device_count": n_devices,
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "git_sha": sha,
        # process-wide telemetry counters at provenance time: cache
        # hits/misses, chunks/configs streamed, evals/s inputs — lands in
        # every BENCH_*.json that embeds provenance()
        "metrics": obs_snapshot(),
    }


def bench_chunked(workload: str, quick: bool) -> dict:
    """Streamed sweep throughput over the widened grid (no per-config
    Python objects anywhere: SoA chunks in, Pareto front out) — the
    serial per-chunk loop vs the double-buffered pipeline (synthesize
    chunk i+1 on the host while the kernel maps chunk i), with identical
    fronts asserted and the overlap fraction recorded."""
    wl = get_workload(workload)
    grid = _CHUNKED_QUICK if quick else _CHUNKED_FULL
    # quick mode streams ~15k configs: a small chunk keeps several chunks
    # in flight so the smoke run exercises the double-buffered pipeline
    chunk_size = 4096 if quick else 32768

    def space():
        return design_space_soa(chunk_size=chunk_size, **grid)

    n = sum(len(s["pe_rows"]) for s in space())
    out: dict = {"chunked_n_configs": n, "chunked_chunk_size": chunk_size}
    backends = ["numpy"]
    try:
        resolve_backend("jax")
        backends.append("jax")
    except RuntimeError:
        pass
    for backend in backends:
        reps = 1 if quick else 3
        fronts = {}
        for mode, overlap in (("serial", False), ("pipelined", True)):
            best = float("inf")
            res = best_res = None
            for _ in range(reps + 1):       # +1 warmup (page/jit caches)
                t0 = time.perf_counter()
                res = sweep_chunked(wl, space(), backend=backend,
                                    chunk_size=chunk_size, overlap=overlap)
                dt = time.perf_counter() - t0
                if dt < best:
                    best, best_res = dt, res
            fronts[mode] = res.front_metrics
            out[f"chunked_{backend}_{mode}_s"] = best
            out[f"chunked_{backend}_{mode}_configs_per_s"] = n / best
            # stage accounting from the rep that set the headline time
            out[f"chunked_{backend}_{mode}_synth_s"] = \
                best_res.timings["synth_s"]
            out[f"chunked_{backend}_{mode}_kernel_wait_s"] = \
                best_res.timings["kernel_wait_s"]
            out[f"chunked_{backend}_front_size"] = res.front_size
        # depth-k prefetch scaling: one timed run per depth, with the
        # stage accounting (sweep.kernel / sweep.synthesize span sums,
        # surfaced through timings) turned into device-side throughput
        # and per-depth overlap fraction
        for depth in (1, 2, 4):
            t0 = time.perf_counter()
            res = sweep_chunked(wl, space(), backend=backend,
                                chunk_size=chunk_size, overlap=True,
                                prefetch_depth=depth)
            dt = time.perf_counter() - t0
            fronts[f"depth{depth}"] = res.front_metrics
            tm = res.timings
            out[f"chunked_{backend}_depth{depth}_s"] = dt
            out[f"chunked_{backend}_depth{depth}_configs_per_s"] = n / dt
            # configs over kernel-stage busy time (dispatch -> finalize
            # span of every chunk): the accelerator-bound ceiling the
            # prefetch queue is trying to reach
            busy = tm["kernel_busy_s"]
            if busy > 0:
                out[f"chunked_{backend}_depth{depth}"
                    f"_device_configs_per_s"] = n / busy
            # stage overlap: (synth + kernel_wait) / wall > 1 means the
            # host and kernel stages ran concurrently (cf. obs report)
            wall = tm["wall_s"]
            if wall > 0:
                out[f"chunked_{backend}_depth{depth}_overlap_fraction"] \
                    = max(0.0, min(1.0, (tm["synth_s"]
                                         + tm["kernel_wait_s"]) / wall
                                   - 1.0))
        # overlap is an invisible optimization: same front, bit for bit,
        # at every prefetch depth
        out[f"chunked_{backend}_pipeline_front_identical"] = bool(all(
            np.array_equal(fronts["serial"][m], fronts[mode][m])
            for mode in fronts if mode != "serial"
            for m in fronts["serial"]))
        serial_s = out[f"chunked_{backend}_serial_s"]
        pipe_s = out[f"chunked_{backend}_pipelined_s"]
        out[f"chunked_{backend}_pipeline_speedup"] = serial_s / pipe_s
        # fraction of the serial wall time the pipeline hid
        out[f"chunked_{backend}_overlap_fraction"] = \
            max(0.0, 1.0 - pipe_s / serial_s)
        # headline chunked numbers stay the (default) pipelined path
        out[f"chunked_{backend}_s"] = pipe_s
        out[f"chunked_{backend}_configs_per_s"] = n / pipe_s
    out["chunked_configs_per_s"] = max(
        out[f"chunked_{b}_configs_per_s"] for b in backends)
    return out


def bench_jax(workload: str, configs, quick: bool) -> dict:
    """The x64-free jit path on the paper space: parity vs numpy + warm
    throughput (post-compile)."""
    try:
        resolve_backend("jax")
    except RuntimeError as exc:
        return {"jax_available": False, "jax_error": str(exc)}
    rn = explore(workload, configs, backend="numpy")
    rj = explore(workload, configs, backend="jax")      # compiles
    hn, hj = rn.headline_ratios(), rj.headline_ratios()
    rel = max(abs(hj[k] - hn[k]) / abs(hn[k]) for k in hn)
    reps = 3 if quick else 10
    warm_s = _best_of(lambda: explore(workload, configs, backend="jax"),
                      reps)
    return {
        "jax_available": True,
        "jax_warm_s": warm_s,
        "jax_warm_configs_per_s": len(configs) / warm_s,
        "jax_vs_numpy_headline_rel": rel,
    }


def bench_pallas(workload: str, quick: bool) -> dict:
    """Interpret-mode Pallas sweep kernel parity against the exact numpy
    kernel over the committed chunked stream (quick: the smoke grid;
    full: the whole ~103k-config grid), gated at ≤1e-6 relative."""
    try:
        resolve_backend("jax")
    except RuntimeError as exc:
        return {"pallas_available": False, "pallas_error": str(exc)}
    from repro.core.accelerator import design_space_soa
    from repro.core.dse_batch import (AGGREGATE_OUTPUTS, _make_cfg_lay,
                                      _sweep_kernel, _workload_batch)
    from repro.core.synthesis import synthesize_soa
    from repro.kernels.sweep_kernel import sweep_aggregates_pallas

    wl = get_workload(workload)
    wb = _workload_batch(wl)
    grid = _CHUNKED_QUICK if quick else _CHUNKED_FULL
    chunk_size = 4096
    max_rel = 0.0
    n_checked = 0
    t_pallas = 0.0
    for soa in design_space_soa(chunk_size=chunk_size, **grid):
        cols = synthesize_soa(soa)
        cfg, lay = _make_cfg_lay(soa, cols, wb)
        t0 = time.perf_counter()
        got = {k: np.asarray(v) for k, v in
               sweep_aggregates_pallas(cfg, lay, interpret=True).items()}
        t_pallas += time.perf_counter() - t0
        want = _sweep_kernel(np, cfg, lay, outputs="aggregates")
        for k in AGGREGATE_OUTPUTS:
            w = np.asarray(want[k], dtype=np.float64)
            rel = np.max(np.abs(got[k] - w)
                         / np.maximum(np.abs(w), 1e-30))
            max_rel = max(max_rel, float(rel))
        n_checked += len(soa["pe_rows"])
    return {
        "pallas_available": True,
        "pallas_parity_n_configs": n_checked,
        "pallas_interpret_max_rel": max_rel,
        "pallas_interpret_configs_per_s": n_checked / t_pallas,
    }


def bench(workload: str = "vgg16", quick: bool = False) -> dict:
    configs = list(design_space())
    if quick:
        configs = list(itertools.islice(configs, 0, None, 4))  # every 4th
    n = len(configs)
    reps_scalar = 1 if quick else 3
    reps_batched = 3 if quick else 10

    scalar_s = _best_of(lambda: explore_scalar(workload, configs),
                        reps_scalar)

    def cold():
        clear_synthesis_cache()
        explore(workload, configs, backend="numpy")

    cold_s = _best_of(cold, reps_batched)
    warm_s = _best_of(lambda: explore(workload, configs, backend="numpy"),
                      reps_batched)

    # identical results is part of the contract, not just speed — pinned
    # to the numpy engine (the bit-exact one on every host; jax parity is
    # gated separately at 1e-6)
    r_scalar = explore_scalar(workload, configs).headline_ratios()
    r_batched = explore(workload, configs,
                        backend="numpy").headline_ratios()
    identical = r_scalar == r_batched

    # multi-workload amortization: one synthesis pass, three mapping passes
    wls = ("vgg16", "resnet34", "resnet50")
    clear_synthesis_cache()
    t0 = time.perf_counter()
    explore_many(wls, configs)
    many_s = time.perf_counter() - t0

    out = {
        "workload": workload,
        "quick": quick,
        "n_configs": n,
        "scalar_s": scalar_s,
        "scalar_configs_per_s": n / scalar_s,
        "batched_cold_s": cold_s,
        "batched_cold_configs_per_s": n / cold_s,
        "batched_warm_s": warm_s,
        "batched_warm_configs_per_s": n / warm_s,
        "speedup_cold": scalar_s / cold_s,
        "speedup_warm": scalar_s / warm_s,
        "explore_many_3wl_s": many_s,
        "explore_many_configs_per_s": 3 * n / many_s,
        "headline_ratios_identical": identical,
        "synthesis_cache": synthesis_cache_stats(),
        "provenance": provenance(),
    }
    out.update(bench_jax(workload, configs, quick))
    out.update(bench_chunked(workload, quick))
    out.update(bench_pallas(workload, quick))
    if not quick:
        # also record the quick-mode cold number so the CI smoke gate can
        # compare like-for-like (quick's smaller space has proportionally
        # more fixed overhead per config)
        q_configs = list(itertools.islice(design_space(), 0, None, 4))

        def q_cold():
            clear_synthesis_cache()
            explore(workload, q_configs, backend="numpy")

        q_s = _best_of(q_cold, reps_batched)
        out["quick_cold_configs_per_s"] = len(q_configs) / q_s
    return out


def check_against(r: dict, baseline_path: pathlib.Path) -> None:
    """CI regression gate: fail if cold throughput fell >3x below the
    committed baseline (machine differences absorbed by the 3x margin).

    A quick-mode run compares against the baseline's quick-mode number
    (recorded by every full run) so the gate is like-for-like; a
    full-mode baseline value is the fallback for older baselines.
    """
    base = json.loads(baseline_path.read_text())
    if r["quick"] and "quick_cold_configs_per_s" in base:
        base_cps = base["quick_cold_configs_per_s"]
        label = "quick baseline"
    else:
        base_cps = base["batched_cold_configs_per_s"]
        label = "baseline"
    got_cps = r["batched_cold_configs_per_s"]
    print(f"regression check: cold {got_cps:.0f} configs/s "
          f"vs {label} {base_cps:.0f} (floor {base_cps / 3:.0f})")
    if got_cps * 3.0 < base_cps:
        raise SystemExit(
            f"cold sweep regressed >3x: {got_cps:.0f} configs/s vs "
            f"{label} {base_cps:.0f}")


def run():
    """benchmarks/run.py entry: CSV rows (name, us_per_call, derived)."""
    r = bench(quick=True)
    n = r["n_configs"]
    rows = [
        ("dse_sweep/scalar", r["scalar_s"] / n * 1e6,
         f"configs_per_s={r['scalar_configs_per_s']:.0f}"),
        ("dse_sweep/batched_cold", r["batched_cold_s"] / n * 1e6,
         f"speedup={r['speedup_cold']:.1f}x"),
        ("dse_sweep/batched_warm", r["batched_warm_s"] / n * 1e6,
         f"speedup={r['speedup_warm']:.1f}x"),
        ("dse_sweep/identical", 0.0,
         str(r["headline_ratios_identical"])),
    ]
    if r.get("jax_available"):
        rows.append(("dse_sweep/jax_warm", r["jax_warm_s"] / n * 1e6,
                     f"headline_rel={r['jax_vs_numpy_headline_rel']:.1e}"))
    rows.append(("dse_sweep/chunked", 1e6 / r["chunked_configs_per_s"],
                 f"configs_per_s={r['chunked_configs_per_s']:.0f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced space + reps (CI smoke mode)")
    ap.add_argument("--workload", default="vgg16")
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--check-against", type=pathlib.Path, default=None,
                    help="baseline BENCH json; fail on >3x cold regression")
    args = ap.parse_args()

    r = bench(workload=args.workload, quick=args.quick)
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True) + "\n")

    print(f"design points: {r['n_configs']}  workload: {r['workload']}"
          f"{'  (quick)' if r['quick'] else ''}")
    print(f"scalar        {r['scalar_s'] * 1e3:8.1f} ms  "
          f"{r['scalar_configs_per_s']:9.0f} configs/s")
    print(f"batched cold  {r['batched_cold_s'] * 1e3:8.1f} ms  "
          f"{r['batched_cold_configs_per_s']:9.0f} configs/s  "
          f"({r['speedup_cold']:.1f}x)")
    print(f"batched warm  {r['batched_warm_s'] * 1e3:8.1f} ms  "
          f"{r['batched_warm_configs_per_s']:9.0f} configs/s  "
          f"({r['speedup_warm']:.1f}x)")
    if r.get("jax_available"):
        print(f"jax warm      {r['jax_warm_s'] * 1e3:8.1f} ms  "
              f"{r['jax_warm_configs_per_s']:9.0f} configs/s  "
              f"(headline rel {r['jax_vs_numpy_headline_rel']:.1e})")
    print(f"explore_many  {r['explore_many_3wl_s'] * 1e3:8.1f} ms  "
          f"3 workloads, {r['explore_many_configs_per_s']:.0f} configs/s")
    for b in ("numpy", "jax"):
        key = f"chunked_{b}_configs_per_s"
        if key in r:
            print(f"chunked {b:5s} {r[f'chunked_{b}_serial_s'] * 1e3:8.1f}"
                  f" ms serial / {r[f'chunked_{b}_pipelined_s'] * 1e3:.1f}"
                  f" ms pipelined  {r[key]:9.0f} configs/s  "
                  f"(overlap {r[f'chunked_{b}_overlap_fraction']:.0%}, "
                  f"{r['chunked_n_configs']} configs)")
        for d in (1, 2, 4):
            dk = f"chunked_{b}_depth{d}_configs_per_s"
            if dk in r:
                dev = r.get(f"chunked_{b}_depth{d}_device_configs_per_s")
                ov = r.get(f"chunked_{b}_depth{d}_overlap_fraction")
                print(f"  depth={d}   {r[dk]:9.0f} configs/s"
                      + (f"  device {dev:9.0f}/s" if dev else "")
                      + (f"  stage overlap {ov:.0%}"
                         if ov is not None else ""))
    if r.get("pallas_available"):
        print(f"pallas parity {r['pallas_parity_n_configs']} configs  "
              f"max rel {r['pallas_interpret_max_rel']:.1e}  "
              f"({r['pallas_interpret_configs_per_s']:.0f} configs/s "
              f"interpret)")
    print(f"headline ratios identical: {r['headline_ratios_identical']}")
    print(f"wrote {args.out}")

    if args.check_against is not None:
        check_against(r, args.check_against)
    if not r["headline_ratios_identical"]:
        raise SystemExit("batched engine diverged from scalar reference")
    for b in ("numpy", "jax"):
        k = f"chunked_{b}_pipeline_front_identical"
        if k in r and not r[k]:
            raise SystemExit(
                f"pipelined chunked sweep diverged from serial ({b}) "
                f"at some prefetch depth")
    if r.get("pallas_available") \
            and r["pallas_interpret_max_rel"] > 1e-6:
        raise SystemExit(
            "pallas sweep kernel diverged from numpy beyond 1e-6: "
            f"{r['pallas_interpret_max_rel']:.2e}")
    best_pipe = max((r[f"chunked_{b}_pipeline_speedup"]
                     for b in ("numpy", "jax")
                     if f"chunked_{b}_pipeline_speedup" in r),
                    default=None)
    # pipelined >= serial: ~1.0x is measurement noise on a loaded /
    # 1-core host, so the gate only catches the pipeline *actively*
    # hurting throughput — a wide margin in quick (1-rep smoke) mode
    floor = 0.5 if r["quick"] else 0.9
    if best_pipe is not None and best_pipe < floor:
        raise SystemExit(
            f"pipelined chunked sweep slower than serial on every "
            f"backend (best {best_pipe:.3f}x < {floor}x floor)")
    if not r["quick"]:
        if r["speedup_cold"] < 10.0:
            raise SystemExit(
                f"speedup gate failed: {r['speedup_cold']:.1f}x < 10x")
        if r.get("jax_available") \
                and r["jax_vs_numpy_headline_rel"] > 1e-6:
            raise SystemExit(
                "jax backend diverged from numpy beyond 1e-6: "
                f"{r['jax_vs_numpy_headline_rel']:.2e}")


if __name__ == "__main__":
    main()
