"""Paper Fig. 2: PPA model accuracy per PE type (power / perf / area).

Rows: model fit quality (R^2, MAPE) and prediction speedup vs the
synthesis oracle — the paper's claim that fitted models 'significantly
speed up the design space exploration'.
"""

import time

import numpy as np

from repro.core.accelerator import design_space
from repro.core.pe import PEType
from repro.core.ppa_model import fit_ppa_suite
from repro.core.synthesis import synthesize


def run():
    cfgs_by = {t: [c for c in design_space() if c.pe_type == t]
               for t in PEType}
    t0 = time.perf_counter()
    suite, stats = fit_ppa_suite(cfgs_by)
    fit_s = time.perf_counter() - t0

    rows = []
    for key, s in stats.items():
        rows.append((f"fig2/{key}/r2", 0.0, f"{s['r2']:.4f}"))
        rows.append((f"fig2/{key}/mape", 0.0, f"{s['mape']:.4f}"))

    # prediction vs oracle timing (batched model evaluation, the DSE's
    # actual usage pattern; the oracle itself stands in for an hours-long
    # synthesis run — the paper's speedup claim is vs synthesis)
    sample = cfgs_by[PEType.LIGHTPE1]
    t0 = time.perf_counter()
    for c in sample:
        synthesize(c)
    oracle_us = (time.perf_counter() - t0) / len(sample) * 1e6
    # mixed-PE-type batched prediction, the DSE engine's access pattern
    mixed = [c for cs in cfgs_by.values() for c in cs]
    t0 = time.perf_counter()
    suite.predict_batch(mixed)
    model_us = (time.perf_counter() - t0) / len(mixed) * 1e6
    rows.append(("fig2/oracle_eval", oracle_us, "us_per_design"))
    rows.append(("fig2/model_eval", model_us,
                 f"vs_synthesis_flow~hours_per_design"))
    rows.append(("fig2/fit_total", fit_s * 1e6,
                 f"{sum(len(v) for v in cfgs_by.values())}_designs"))
    return rows
