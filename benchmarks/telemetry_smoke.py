"""Telemetry smoke: instrumented sweep + search produce valid traces and
the disabled path stays free (the `telemetry-smoke` CI job).

Four gates:

* **trace validity** — an instrumented ``sweep_chunked`` + ``nsga2`` run
  exports Chrome ``trace_event`` JSON that passes the schema check and
  carries the expected stage spans (pull / synthesize / dispatch /
  kernel / reduce, per-generation spans, evaluate spans).
* **metrics content** — the registry snapshot after the run has
  per-stage times (``sweep.synth_s`` / ``sweep.kernel_wait_s`` /
  ``sweep.wall_s``), synthesis-cache hit/miss counters, and the evals/s
  inputs (``explore.requested_evals`` / ``explore.eval_seconds``).
* **bit-identity** — running the same sweep and search with telemetry
  enabled vs disabled yields byte-identical Pareto fronts and identical
  synthesis-cache hit/miss accounting.
* **overhead** — enabling telemetry costs <2% wall time on the sweep
  (min-of-N repeats, interleaved enabled/disabled so machine drift hits
  both arms, and up to three measurement rounds so one noisy round
  cannot fail the job).

Writes ``--out`` JSON and ``--trace-out`` (the Chrome trace, uploaded as
a CI artifact; load it at https://ui.perfetto.dev).

  PYTHONPATH=src python benchmarks/telemetry_smoke.py
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
from dse_sweep_bench import provenance  # noqa: E402  (shared helper)

from repro import obs  # noqa: E402
from repro.core.accelerator import design_space_soa  # noqa: E402
from repro.core.dse import ExploreSpec, run  # noqa: E402
from repro.core.synthesis import PersistentSynthesisCache  # noqa: E402
from repro.core.workloads import get_workload  # noqa: E402

CHUNK = 1024
GRID = dict(glb_kbs=(64, 128, 256, 512),
            bws=tuple(float(b) for b in np.linspace(2.0, 64.0, 24)))

EXPECTED_SWEEP_SPANS = ("sweep_chunked", "sweep.pull", "sweep.synthesize",
                        "sweep.dispatch", "sweep.kernel", "sweep.reduce")
EXPECTED_SEARCH_SPANS = ("nsga2.generation", "explore.evaluate")


def _space():
    return design_space_soa(chunk_size=CHUNK, **GRID)


def _sweep(telemetry, cache=None):
    spec = ExploreSpec.single("vgg16", _space(), chunk_size=CHUNK,
                              backend="numpy", cache=cache,
                              save_cache=False, telemetry=telemetry)
    return run(spec)


def _search(telemetry):
    spec = ExploreSpec.mixed("vgg16", method="nsga2", budget=96,
                             seed=7, backend="numpy",
                             telemetry=telemetry, pop_size=16)
    return run(spec)


def instrumented_run(trace_out: pathlib.Path | None) -> tuple[dict, list]:
    """One instrumented sweep + nsga2; returns (report, failures)."""
    failures: list[str] = []
    obs.reset_metrics()
    obs.configure(enabled=True, reset=True)
    try:
        sweep = _sweep(telemetry=None)        # switch already on
        search = _search(telemetry=None)
    finally:
        obs.disable()

    doc = obs.export_chrome_trace(trace_out)
    problems = obs.validate_chrome_trace(doc)
    if problems:
        failures.append(f"chrome trace schema: {problems[:5]}")
    names = {e["name"] for e in doc["traceEvents"]}
    for want in EXPECTED_SWEEP_SPANS + EXPECTED_SEARCH_SPANS:
        if want not in names:
            failures.append(f"missing expected span {want!r}")
    # the exported file must round-trip as JSON
    if trace_out is not None:
        reloaded = json.loads(trace_out.read_text())
        if obs.validate_chrome_trace(reloaded):
            failures.append("trace JSON file failed schema after reload")

    snap = obs.snapshot()
    for key in ("sweep.wall_s", "sweep.synth_s", "sweep.kernel_wait_s",
                "sweep.chunks", "sweep.configs", "synth_cache.hits",
                "synth_cache.misses", "explore.requested_evals",
                "explore.kernel_evals", "explore.eval_seconds",
                "nsga2.generations"):
        if key not in snap:
            failures.append(f"metrics snapshot missing {key}")
    summary = obs.summarize(metrics=snap)
    derived = summary["derived"]
    for key in ("synth_cache_hit_rate", "sweep_configs_per_s",
                "explore_evals_per_s"):
        if key not in derived:
            failures.append(f"derived summary missing {key}")
    report = {
        "n_trace_events": len(doc["traceEvents"]),
        "span_names": sorted(names),
        "metrics": snap,
        "derived": derived,
        "sweep_front_size": sweep.front_size,
        "search_front_size": search.front_size,
        "search_eval_seconds": search.stats["eval_seconds"],
    }
    print(obs.render_text(summary), file=sys.stderr)
    return report, failures


def bit_identity() -> tuple[dict, list]:
    """Telemetry on vs off: identical fronts, identical cache counters."""
    failures: list[str] = []

    def sweep_with(telemetry):
        cache = PersistentSynthesisCache()
        res = _sweep(telemetry=telemetry, cache=cache)
        return res, {"hits": cache.hits, "misses": cache.misses}

    obs.disable()
    ref, ref_acct = sweep_with(False)
    ref_search = _search(False)
    on, on_acct = sweep_with(True)
    on_search = _search(True)

    front_identical = ref.front_size == on.front_size and all(
        np.array_equal(ref.front_metrics[m], on.front_metrics[m])
        for m in ref.front_metrics) and all(
        np.array_equal(ref.front_soa[k], on.front_soa[k])
        for k in ref.front_soa)
    if not front_identical:
        failures.append("sweep front changed when telemetry was enabled")
    if ref_acct != on_acct:
        failures.append(
            f"cache accounting changed under telemetry: {ref_acct} "
            f"vs {on_acct}")
    search_identical = (
        np.array_equal(ref_search.genomes, on_search.genomes)
        and np.array_equal(ref_search.front_objectives,
                           on_search.front_objectives))
    if not search_identical:
        failures.append("nsga2 front changed when telemetry was enabled")
    if obs.is_enabled():
        failures.append("ExploreSpec(telemetry=True) leaked: the global "
                        "switch is still on after run()")
    return {
        "front_identical": front_identical,
        "cache_accounting_identical": ref_acct == on_acct,
        "search_identical": search_identical,
        "cache_accounting": ref_acct,
    }, failures


def overhead_gate(limit: float, reps: int, rounds: int
                  ) -> tuple[dict, list]:
    """min-of-N wall time, telemetry on vs off, interleaved arms."""
    soa_all = list(_space())       # materialize once: feed cost is shared
    wl = get_workload("vgg16")

    from repro.core.dse_batch import _sweep_chunked

    def one(telemetry: bool) -> float:
        if telemetry:
            obs.configure(enabled=True, reset=True)
        else:
            obs.disable()
        try:
            t0 = time.perf_counter()
            _sweep_chunked(wl, iter(soa_all), chunk_size=CHUNK,
                           backend="numpy")
            return time.perf_counter() - t0
        finally:
            obs.disable()

    one(False)                     # warm page / allocator caches
    ratios = []
    for _ in range(rounds):
        best_off = best_on = float("inf")
        for _ in range(reps):      # interleave so drift hits both arms
            best_off = min(best_off, one(False))
            best_on = min(best_on, one(True))
        ratios.append(best_on / best_off)
        if ratios[-1] < limit:
            break
    failures = []
    if min(ratios) >= limit:
        failures.append(
            f"telemetry overhead {min(ratios):.4f}x >= {limit}x gate "
            f"(ratios per round: {[f'{r:.4f}' for r in ratios]})")
    return {"overhead_ratios": ratios, "overhead_best": min(ratios),
            "overhead_limit": limit}, failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=pathlib.Path,
                    default=pathlib.Path("/tmp/bench_telemetry_smoke.json"))
    ap.add_argument("--trace-out", type=pathlib.Path,
                    default=pathlib.Path("/tmp/telemetry_smoke_trace.json"))
    ap.add_argument("--overhead-limit", type=float, default=1.02)
    ap.add_argument("--overhead-reps", type=int, default=5)
    ap.add_argument("--overhead-rounds", type=int, default=3)
    ap.add_argument("--skip-overhead", action="store_true",
                    help="trace/metrics/bit-identity gates only")
    args = ap.parse_args()

    failures: list[str] = []
    r: dict = {"provenance": provenance()}

    rep, f = instrumented_run(args.trace_out)
    r.update(rep)
    failures += f

    rep, f = bit_identity()
    r.update(rep)
    failures += f

    if not args.skip_overhead:
        rep, f = overhead_gate(args.overhead_limit, args.overhead_reps,
                               args.overhead_rounds)
        r.update(rep)
        failures += f

    r["failures"] = failures
    args.out.write_text(json.dumps(r, indent=2, sort_keys=True,
                                   default=str) + "\n")
    print(f"trace events: {r['n_trace_events']}  "
          f"front sizes: sweep={r['sweep_front_size']} "
          f"search={r['search_front_size']}")
    print(f"bit-identity: front={r['front_identical']} "
          f"cache={r['cache_accounting_identical']} "
          f"search={r['search_identical']}")
    if "overhead_best" in r:
        print(f"overhead: {r['overhead_best']:.4f}x "
              f"(gate {r['overhead_limit']}x)")
    print(f"wrote {args.out} and {args.trace_out}")
    if failures:
        raise SystemExit("telemetry smoke FAILED:\n  "
                         + "\n  ".join(failures))
    print("telemetry smoke OK")


if __name__ == "__main__":
    main()
