"""Quantization-mode accuracy ablation (paper Sec. 3.2: LightPEs achieve
their gains "with only slight accuracy degradation", citing LightNN).

Trains the same smoke model under each execution mode (paper PE-type
analogue) on the same data/seed and reports the final training loss:
fp32 / bf16 / w8a8 (LightPE-2) / w4a8_pow2 (LightPE-1).
"""

import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import Model
from repro.optim import adamw

MODES = ("fp32", "bf16", "w8a8", "w4a8_pow2")


def _train_mode(mode: str, steps: int = 40):
    cfg = dataclasses.replace(reduced(get_config("phi4-mini-3.8b")),
                              quant=mode)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=4)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8))

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt, _ = adamw.update(ocfg, grads, opt, params)
        return params, opt, loss

    loss = None
    for s in range(steps):
        params, opt, loss = step(params, opt, data.batch(s))
    return float(loss)


def run():
    rows = []
    t0 = time.perf_counter()
    losses = {}
    for mode in MODES:
        losses[mode] = _train_mode(mode)
        rows.append((f"quant_acc/{mode}_final_loss", 0.0,
                     f"{losses[mode]:.4f}"))
    base = losses["fp32"]
    for mode in MODES[1:]:
        rows.append((f"quant_acc/{mode}_degradation", 0.0,
                     f"{losses[mode] - base:+.4f}_nats"))
    rows.append(("quant_acc/total", (time.perf_counter() - t0) * 1e6,
                 f"{len(MODES)}x40_steps"))
    return rows
